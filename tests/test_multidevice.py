"""Multi-device integration tests.

These need >1 XLA device, so they run in subprocesses with
``xla_force_host_platform_device_count`` set — unit tests in-process keep
seeing the single real CPU device (dry-run isolation rule).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_controller_full_lifecycle_and_failover(tmp_path):
    """Two tenant blocks run concurrently; chip failure triggers automatic
    re-allocation + checkpoint restore; elastic resize reshards state."""
    out = run_py(f"""
    import jax
    import repro.configs as C
    from repro.core.controller import ClusterController
    from repro.core.runtime import JobSpec
    from repro.core.topology import Topology
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig

    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    ctl = ClusterController(topo, ckpt_root={str(tmp_path)!r})
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=8, microbatch=2)
    a1 = ctl.register("alice", "dense", 4, arch="deepseek_7b")
    a2 = ctl.register("bob", "hybrid", 2, arch="zamba2_2p7b")
    g1 = ctl.review(a1); g2 = ctl.review(a2)
    ctl.partitioner.check_invariants()
    ctl.confirm(a1, g1.token); ctl.confirm(a2, g2.token)
    ctl.activate(a1, JobSpec(C.get_smoke("deepseek_7b"), shape,
                             opt=OptConfig(warmup_steps=2, total_steps=10)))
    ctl.activate(a2, JobSpec(C.get_smoke("zamba2_2p7b"), shape,
                             opt=OptConfig(warmup_steps=2, total_steps=10)))
    ctl.run(a1); ctl.run(a2)
    rep = ctl.interference_report()
    assert rep.isolated, rep.shared_links
    ctl.step_all(rounds=2)
    ctl.runtimes[a1].save(async_=False)
    loss_before = None

    failed = ctl.inject_chip_failure(g1.coords[0])
    assert failed == a1
    assert ctl.registry.get(a1).state.value == "running"
    ctl.step_all(rounds=1)
    st = ctl.runtimes[a1]
    assert st.step_count >= 2   # restored at checkpointed step, stepped once

    ctl.resize_block(a2, 4)
    ctl.step_all(rounds=1)
    assert ctl.registry.get(a2).grant.n_chips == 4
    ctl.partitioner.check_invariants()
    res = ctl.download(a1)
    assert res["checkpoints"], res
    ctl.expire(a1); ctl.expire(a2)
    assert len(ctl.partitioner.free_chips()) == topo.n_chips - 1  # 1 dead
    print("LIFECYCLE_OK")
    """, devices=16)
    assert "LIFECYCLE_OK" in out


@pytest.mark.slow
def test_sharded_equals_single_device_loss():
    """The same train step on a 4-device (2,2) mesh and on a (1,1) mesh gives
    the same loss (sharding does not change semantics)."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.data import pipeline
    from repro.models.config import ShapeConfig
    from repro.sharding import ctx as shard_ctx, plans
    from repro.train import optimizer as opt_lib, train_step as train_lib

    cfg = C.get_smoke("llama4_maverick_400b")   # moe: the interesting case
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=8, microbatch=2)
    opt_cfg = opt_lib.OptConfig(warmup_steps=1, total_steps=4)
    data = pipeline.DataIterator(cfg, shape)
    batch = data.batch(0)

    def run_on(mesh_shape):
        import numpy as np
        devs = np.asarray(jax.devices()[:mesh_shape[0]*mesh_shape[1]])
        mesh = jax.sharding.Mesh(devs.reshape(mesh_shape), ("data","model"))
        axes = plans.MeshAxes(dp=("data",), model="model")
        ctx = shard_ctx.ShardCtx(mesh, ("data",), "model")
        state_abs = train_lib.abstract_train_state(cfg, opt_cfg)
        p_spec = plans.param_specs(state_abs["params"], mesh, axes)
        spec = {"params": p_spec,
                "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
        sh = plans.to_shardings(spec, mesh)
        step = train_lib.make_train_step(cfg, shape, opt_cfg)
        def fn(state, b):
            with shard_ctx.use(ctx):
                return step(state, b)
        jstep = jax.jit(fn, in_shardings=(sh, None), out_shardings=(sh, None))
        init = jax.jit(lambda k: train_lib.make_train_state(cfg, k, opt_cfg),
                       out_shardings=sh)
        state = init(jax.random.PRNGKey(0))
        losses = []
        for i in range(3):
            state, m = jstep(state, data.batch(i))
            losses.append(float(m["loss"]))
        return losses

    l_multi = run_on((2, 2))
    l_single = run_on((1, 1))
    np.testing.assert_allclose(l_multi, l_single, rtol=2e-2, atol=2e-2)
    print("EQUAL_OK", l_multi, l_single)
    """, devices=8)
    assert "EQUAL_OK" in out


@pytest.mark.slow
def test_overlap_comm_matches_sequential_allreduce():
    """``overlap_comm=True`` (per-microbatch int8 compressed psum over the
    pod axis, folded into the accumulation scan) matches the baseline
    GSPMD fp32 all-reduce within compression tolerance on a real 2-pod
    mesh — params replicated over pod, FSDP/TP over the auto axes."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro.configs as C
    from repro.data import pipeline
    from repro.models.config import ShapeConfig
    from repro.sharding import ctx as shard_ctx, plans
    from repro.train import optimizer as opt_lib, train_step as train_lib

    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=8, microbatch=2)
    opt_cfg = opt_lib.OptConfig(warmup_steps=1, total_steps=8)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    axes = plans.MeshAxes(dp=("data",), model="model")  # pod = replica axis
    ctx = shard_ctx.ShardCtx(mesh, ("data",), "model")
    state_abs = train_lib.abstract_train_state(cfg, opt_cfg)
    p_spec = plans.param_specs(state_abs["params"], mesh, axes)
    spec = {"params": p_spec,
            "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
    sh = plans.to_shardings(spec, mesh)
    b_sh = NamedSharding(mesh, P(("pod", "data")))
    data = pipeline.DataIterator(cfg, shape)

    def run(**kw):
        step = train_lib.make_train_step(cfg, shape, opt_cfg, **kw)
        def fn(state, b):
            with shard_ctx.use(ctx):
                return step(state, b)
        jstep = jax.jit(fn, in_shardings=(sh, None), out_shardings=(sh, None))
        init = jax.jit(lambda k: train_lib.make_train_state(cfg, k, opt_cfg),
                       out_shardings=sh)
        state = init(jax.random.PRNGKey(0))
        losses = []
        for i in range(3):
            b = jax.tree.map(lambda x: jax.device_put(x, b_sh), data.batch(i))
            state, m = jstep(state, b)
            losses.append(float(m["loss"]))
        return losses

    base = run()
    over = run(overlap_comm=True, mesh=mesh)
    np.testing.assert_allclose(over, base, rtol=0.05, atol=0.05)
    print("OVERLAP_OK", base, over)
    """, devices=8)
    assert "OVERLAP_OK" in out


@pytest.mark.slow
def test_grad_compression_shard_map():
    """int8 compressed cross-pod psum inside partial-auto shard_map matches
    the exact psum within quantization error."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    from repro.train import grad_compression as gc

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 64))  # per-pod grads

    @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
             axis_names={"pod"})   # manual over pod, GSPMD-auto elsewhere
    def compressed(gp):
        err = jnp.zeros_like(gp)
        red, _ = gc.compressed_psum_pod({"g": gp}, {"g": err}, mesh, "pod")
        return red["g"]

    got = jax.jit(compressed)(g)   # partial-auto requires jit on jax<=0.4
    want = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
    np.testing.assert_allclose(got, want, atol=0.05)

    red, new_err = gc.compressed_allreduce(
        {"g": g}, {"g": jnp.zeros_like(g)}, mesh, "pod")
    np.testing.assert_allclose(red["g"], want, atol=0.05)
    assert new_err["g"].shape == g.shape
    print("COMPRESS_OK")
    """, devices=8)
    assert "COMPRESS_OK" in out
