"""Autostep engine + event-feed scale-out: inline-mode determinism vs
client-driven stepping, pacing/fairness, run-until termination,
autostep x preemption drain/re-arm, periodic checkpoints, and the
per-block event-ring isolation."""
import time

import jax
import pytest

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.events import EventBus
from repro.core.inflight import InflightWindow
from repro.core.runtime import SimJobSpec
from repro.core.topology import Topology
from repro.engine import BlockView, PacingPolicy


def make_daemon(tmp_path, pod_x=4, pod_y=2, **kw):
    topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root=str(tmp_path / "ckpt"), **kw)


SIM = SimJobSpec(step_s=0.0005, ckpt_every=2)


def drive_until(daemon, apps, state=BlockState.DONE, timeout=30.0,
                now=None):
    """Inline engine rounds until every app reaches ``state``."""
    deadline = time.monotonic() + timeout
    while not all(daemon.registry.get(a).state == state for a in apps):
        daemon.autostep_round(now=now)
        time.sleep(0.0002)
        assert time.monotonic() < deadline, "autostep never finished"


# ------------------------------------------------------------ determinism

def test_inline_autostep_matches_client_driven_trace(tmp_path):
    """Same workload, two drivers: the engine's step/event stream is
    indistinguishable from client-driven ``run_steps`` — identical step
    counts, identical per-block event payload fields, identical Monitor
    accounting (EWMA from the same model-time now= plumbing)."""
    def workload(d, engine: bool):
        a, g = d.submit("alice", "wl", 4, job=SIM, now=100.0)
        b, g2 = d.submit("bob", "wl", 4, job=SIM, now=100.0)
        assert g is not None and g2 is not None
        if engine:
            d.autostep_enable(a, until_steps=12, now=100.0)
            d.autostep_enable(b, until_steps=12, now=100.0)
            drive_until(d, [a, b], now=101.0)
        else:
            d.run_steps({a: 12, b: 12})
        return a, b

    d1 = make_daemon(tmp_path / "c")
    a1, b1 = workload(d1, engine=False)
    d2 = make_daemon(tmp_path / "e")
    a2, b2 = workload(d2, engine=True)

    for d, a, b in [(d1, a1, b1), (d2, a2, b2)]:
        for app in (a, b):
            assert d.runtime(app).step_count == 12
            bid = d.registry.get(app).block_id
            assert d.monitor.stats[bid].steps == 12
            assert d.monitor.stats[bid].ewma_step_s is not None

    def step_payload_keys(d, app):
        evs = [e for e in d.bus.events_since(0, app_id=app)
               if e.kind == "step"]
        return [sorted(e.payload) for e in evs]

    # the engine publishes the same step payload shape in the same count
    assert step_payload_keys(d1, a1) == step_payload_keys(d2, a2)
    # lifecycle through RUNNING is identical; the engine then adds the
    # run-until DONE transition on top
    def states(d, app):
        return [e.payload["state"]
                for e in d.bus.events_since(0, app_id=app)
                if e.kind == "state"]
    assert states(d1, a1) == ["approved", "confirmed", "active", "running"]
    assert states(d2, a2) == ["approved", "confirmed", "active", "running",
                              "done"]


def test_engine_inert_unless_enabled(tmp_path):
    """No drives -> a round is a no-op and publishes nothing: the
    deterministic mode's event stream is bit-for-bit the pre-engine one
    (what keeps policy_admission.py results unchanged)."""
    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "plain", 4, job=SIM)
    seq = d.bus.latest_seq
    assert d.autostep_round() == 0
    assert d.autostep_round(now=123.0) == 0
    assert d.bus.latest_seq == seq
    assert not d.engine.armed


# ------------------------------------------------------- pacing / fairness

def test_pacing_policy_weighted_fair_interleave():
    views = [BlockView("hi", priority=4, n_chips=4, room=100),
             BlockView("lo", priority=0, n_chips=4, room=100)]
    plan = PacingPolicy(priority_weight=0.5).allocate(views, budget=30)
    assert len(plan) == 30
    # weight 3.0 vs 1.0 -> ~3:1 split of the slots
    assert 20 <= plan.count("hi") <= 25
    assert plan.count("lo") >= 5
    # a full window (room=0) is structural backpressure: no slots at all
    views = [BlockView("full", priority=9, n_chips=1, room=0),
             BlockView("open", priority=0, n_chips=1, room=8)]
    plan = PacingPolicy().allocate(views, budget=8)
    assert plan == ["open"] * 8


def test_pacing_policy_deadline_boost():
    tight = BlockView("tight", n_chips=4, slack_s=2.0, room=100)
    loose = BlockView("loose", n_chips=4, slack_s=1e6, room=100)
    pol = PacingPolicy(boost_slack_s=30.0, deadline_boost=4.0)
    assert pol.weight(tight) > 2.5 * pol.weight(loose)
    plan = pol.allocate([tight, loose], budget=24)
    assert plan.count("tight") > plan.count("loose")


def test_autostep_rate_cap_on_model_clock(tmp_path):
    """max_rate_hz is enforced by the per-drive token bucket on the same
    clock the rounds run on (model time here: deterministic)."""
    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "paced", 4, job=SimJobSpec(step_s=0.0))
    d.autostep_enable(a, max_rate_hz=10.0)
    now = 1000.0
    for i in range(200):                     # 2.0 model-seconds of rounds
        d.autostep_round(now=now + i * 0.01)
    # 10 steps/s * 2 s (+ the initial one-token allowance and burst slop)
    steps = d.runtime(a).step_count
    assert 18 <= steps <= 26, steps
    d.autostep_pace(a, None)                 # unpace: free running again
    before = d.runtime(a).step_count
    for i in range(20):
        d.autostep_round(now=now + 10 + i * 0.01)
    assert d.runtime(a).step_count - before > 20
    d.autostep_pace(a, 0.0)                  # rate 0 = pause, not unpaced
    paused_at = d.runtime(a).step_count
    for i in range(20):
        d.autostep_round(now=now + 20 + i * 0.01)
    assert d.runtime(a).step_count <= paused_at + d.scheduler.max_inflight
    assert d.engine.enabled(a)               # still armed, just held


# ----------------------------------------------------- run-until / lifecycle

def test_until_steps_exact_completion_and_done_event(tmp_path):
    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "count", 4, job=SIM)
    d.autostep_enable(a, until_steps=7)
    drive_until(d, [a])
    assert d.runtime(a).step_count == 7          # never overshoots
    assert d.registry.get(a).state == BlockState.DONE
    evs = d.bus.events_since(0, app_id=a)
    autos = [e for e in evs if e.kind == "autostep"]
    assert [e.payload["action"] for e in autos] == ["enabled", "done"]
    assert autos[-1].payload["steps"] == 7
    assert not d.engine.enabled(a)               # drive retired


def test_until_t_stops_dispatching_but_keeps_block_running(tmp_path):
    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "timed", 4, job=SimJobSpec(step_s=0.0))
    d.autostep_enable(a, until_t=2000.0)
    for i in range(10):
        d.autostep_round(now=1999.0)
    assert d.runtime(a).step_count > 0
    # past the stop time: the engine harvests the in-flight stragglers,
    # dispatches nothing new, and disarms once the window is empty
    for _ in range(5):
        d.autostep_round(now=2000.5)
    assert not d.engine.enabled(a)               # disarmed, not DONE
    ran = d.runtime(a).step_count
    d.autostep_round(now=2001.0)
    assert d.runtime(a).step_count == ran        # no further dispatches
    assert d.registry.get(a).state == BlockState.RUNNING


def test_autostep_preempt_drains_publishes_and_rearms(tmp_path):
    """Eviction of an engine-driven block: in-flight completions are
    harvested and *published* before the suspend (Monitor loses nothing),
    the drive survives, and the block autosteps again after auto-resume
    to finish its run-until target."""
    d = make_daemon(tmp_path)
    a, g = d.submit("alice", "victim", 8, job=SIM)
    assert g is not None
    d.autostep_enable(a, until_steps=40)
    deadline = time.monotonic() + 20
    while d.runtime(a).step_count < 10:
        d.autostep_round()
        time.sleep(0.0002)
        assert time.monotonic() < deadline
    hi, g2 = d.submit("bob", "urgent", 8, job=SIM, priority=5)
    assert g2 is not None                        # preempted alice
    blk = d.registry.get(a)
    assert blk.state == BlockState.PREEMPTED
    assert d.engine.enabled(a)                   # drive survived
    assert d.runtime(a).inflight_depth == 0      # drained
    bid = blk.block_id
    # every completed step was published before the suspend
    assert d.monitor.stats[bid].steps == d.runtime(a).step_count
    r = d.autostep_round()                       # idles while evicted
    assert d.registry.get(a).state == BlockState.PREEMPTED
    d.expire(hi)
    d.tick()                                     # auto-resume
    assert d.registry.get(a).state == BlockState.RUNNING
    drive_until(d, [a])
    assert d.runtime(a).step_count == 40
    assert d.monitor.stats[bid].steps == 40


def test_autostep_ckpt_interval_saves_periodically(tmp_path):
    """Engine-side periodic checkpoints: a runtime exposing save()/
    last_saved_step gets saved every ckpt_every completions."""
    class FakeRuntime(InflightWindow):
        def __init__(self):
            self.step_count = 0
            self.last_saved_step = 0
            self.saves = []
            self.suspended = False
            self._init_window()

        def _launch(self):
            return None

        def _token_ready(self, token):
            return True

        def _token_wait(self, token):
            pass

        def _completion_record(self, dispatch_t, token):
            self.step_count += 1
            return {"step_s": 0.001}

        def save(self, async_=True):
            self.saves.append(self.step_count)
            self.last_saved_step = self.step_count

    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "fake", 4, job=SIM)
    rt = FakeRuntime()
    d.ctl.runtimes[a] = rt                       # swap in the probe
    d.autostep_enable(a, until_steps=20, ckpt_every=5)
    drive_until(d, [a])
    assert rt.step_count == 20
    # saves land at interval boundaries as seen per harvest round, so the
    # gap between saves is bounded by ckpt_every + the dispatch window —
    # which bounds progress_lost the same way client-driven saving did
    window = d.scheduler.max_inflight
    assert rt.saves, "no periodic checkpoint under autostep"
    marks = [0] + rt.saves
    gaps = [b - a for a, b in zip(marks, marks[1:])]
    assert all(5 <= g <= 5 + window for g in gaps), rt.saves
    assert rt.saves[-1] >= 20 - (5 + window)


def test_enable_rejects_terminal_and_submit_arms_queued(tmp_path):
    d = make_daemon(tmp_path)
    a, _ = d.submit("alice", "gone", 4, job=SIM)
    d.expire(a)
    with pytest.raises(ValueError):
        d.autostep_enable(a)
    # arming a *queued* block is legal: it steps once admitted
    filler, _ = d.submit("bob", "filler", 8, job=SIM)
    q, g = d.submit("carol", "waits", 8, job=SIM)
    assert g is None
    d.autostep_enable(q, until_steps=5)
    assert d.autostep_round() == 0               # queued: engine idles
    d.expire(filler)                             # frees room; pump admits
    drive_until(d, [q])
    assert d.runtime(q).step_count == 5


# ----------------------------------------------------- event ring isolation

def test_per_block_ring_survives_global_ring_eviction():
    """One hot block's step storm must not evict another block's events:
    per-app queries read the block's own ring."""
    bus = EventBus(history=16, per_block_history=64)
    bus.publish("state", app_id="quiet", state="running")
    first_quiet_seq = bus.latest_seq
    for i in range(200):                          # the storm
        bus.publish("step", app_id="hot", step_s=0.001, n_chips=4)
    bus.publish("state", app_id="quiet", state="done")
    # global ring wrapped long ago: the quiet block's first event is gone
    assert all(e.app_id == "hot" or e.seq > first_quiet_seq
               for e in bus.events_since(0))
    quiet = bus.events_since(0, app_id="quiet")
    assert [e.payload["state"] for e in quiet] == ["running", "done"]
    # the hot block's own ring is bounded, newest-last
    hot = bus.events_since(0, app_id="hot", limit=1000)
    assert len(hot) == 64
    assert hot[-1].seq == bus.latest_seq - 1
    # kind filters and cursors still apply on the per-app path
    assert bus.events_since(0, app_id="quiet", kinds={"state"}) == quiet
    assert bus.events_since(quiet[0].seq, app_id="quiet") == quiet[1:]
