"""Tenancy policy layer: per-user quotas (waitlist-not-deny, quota-busting
victim preference), deadline-slack ordering with hit/miss accounting, gang
all-or-nothing admission/rollback, plus the lifecycle race/accounting fixes
that shipped with it (recover_block allocate-first + deferred requeue,
resize grow-in-place, falsy model-time zero, priority binning, expire
drain)."""
import json
import time

import jax
import pytest

from repro.core.block import BlockState
from repro.core.controller import ClusterController
from repro.core.monitor import Monitor
from repro.core.partition import AllocationError, Partitioner
from repro.core.policy import SchedulingPolicy, UserQuota
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology


def make_ctl(tmp_path, pod_x=4, pod_y=2, n_pods=1, state=False):
    topo = Topology(n_pods=n_pods, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterController(
        topo, devices=[dev] * topo.n_chips,
        ckpt_root=str(tmp_path / "ckpt"),
        state_path=str(tmp_path / "state.json") if state else None)


def submit_running(ctl, user, n_chips, priority=0, step_s=0.001,
                   ckpt_every=0, pod=None):
    app_id, grant = ctl.submit(user, f"{user} job", n_chips,
                               priority=priority, pod=pod)
    assert grant is not None, f"{user} did not fit"
    ctl.confirm(app_id, grant.token)
    ctl.registry.set_state(app_id, BlockState.ACTIVE)
    ctl.registry.set_state(app_id, BlockState.RUNNING)
    ctl.runtimes[app_id] = SimRuntime(step_s, ckpt_every=ckpt_every)
    return app_id


def ownership_snapshot(part: Partitioner):
    return {c: info.owner for c, info in part.chips.items()}


# ------------------------------------------------------------------ quotas

def test_quota_chip_cap_waitlists_not_denies(tmp_path):
    """Over-quota requests wait (QUEUED) even when the pod has room, and
    become admissible as the user's blocks retire."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    ctl.scheduler.policy.set_quota("alice", max_chips=4)
    a1, g1 = ctl.submit("alice", "first", 4)
    assert g1 is not None
    a2, g2 = ctl.submit("alice", "second", 4)        # pod has 4 free...
    assert g2 is None                                # ...but quota says wait
    blk2 = ctl.registry.get(a2)
    assert blk2.state == BlockState.QUEUED           # waitlisted, NOT denied
    assert "quota" in blk2.history[-1][1]
    ctl.tick()                                       # still blocked
    assert ctl.registry.get(a2).state == BlockState.QUEUED
    ctl.expire(a1)                                   # holdings drop to 0
    assert ctl.registry.get(a2).state == BlockState.APPROVED
    ctl.partitioner.check_invariants()


def test_request_exceeding_user_cap_alone_is_denied_not_parked(tmp_path):
    """A request bigger than its user's own chip cap can never become
    admissible (no amount of their blocks retiring helps): deny up front
    like a geometrically-impossible size, don't waitlist forever."""
    ctl = make_ctl(tmp_path)
    ctl.scheduler.policy.set_quota("alice", max_chips=4)
    a, g = ctl.submit("alice", "bigger than my cap", 8)
    assert g is None
    assert ctl.registry.get(a).state == BlockState.DENIED
    assert ctl.scheduler.queue_depth() == 0


def test_quota_chip_seconds_budget_blocks_until_raised(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.scheduler.policy.set_quota("alice", max_chip_seconds=1.0)
    a1 = submit_running(ctl, "alice", 4)
    bid = ctl.registry.get(a1).block_id
    ctl.monitor.record_step(bid, step_s=0.5, n_chips=4)   # 2.0 chip-seconds
    a2, g2 = ctl.submit("alice", "more", 4)
    assert g2 is None                                # budget spent -> wait
    assert ctl.registry.get(a2).state == BlockState.QUEUED
    ctl.scheduler.policy.set_quota("alice", max_chip_seconds=100.0)
    ctl.tick()
    assert ctl.registry.get(a2).state == BlockState.APPROVED


def test_quota_busting_victim_preferred(tmp_path):
    """A running block whose user is over quota is evicted ahead of blocks
    the plain (priority, progress-lost, chips) key would pick."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    a = submit_running(ctl, "alice", 4)
    b = submit_running(ctl, "bob", 4)
    c = submit_running(ctl, "carol", 4)
    d = submit_running(ctl, "dan", 4)
    # bob is normally the cheapest victim (least progress lost)...
    ctl.runtimes[a].step_count = 9
    ctl.runtimes[b].step_count = 0
    ctl.runtimes[c].step_count = 5
    ctl.runtimes[d].step_count = 5
    # ...but alice's cap is lowered under her running block: quota-buster
    ctl.scheduler.policy.set_quota("alice", max_chips=2)
    hi, grant = ctl.submit("eve", "urgent", 4, priority=5)
    assert grant is not None
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    for other in (b, c, d):
        assert ctl.registry.get(other).state == BlockState.RUNNING


def test_gang_quota_counts_whole_footprint(tmp_path):
    """Quota sees the gang's total chips, not each member separately: a
    gang that exceeds the cap outright is denied (it can never fit), while
    one blocked only by current holdings waits for them to retire."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    ctl.scheduler.policy.set_quota("alice", max_chips=6)
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 4), ("eval", 4)])      # 8 > 6: never fits cap
    assert grants is None
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.DENIED

    ctl.scheduler.policy.set_quota("alice", max_chips=8)
    filler = submit_running(ctl, "alice", 4)         # holds 4 of cap 8
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 2), ("eval", 2)])      # 4 held + 4 > 8? no:
    assert grants is not None                        # 8 == cap: admitted
    app_ids2, grants2 = ctl.submit_gang(
        "alice", [("trainer2", 2), ("eval2", 2)])    # 8 held + 4 > 8: wait
    assert grants2 is None
    for a in app_ids2:
        assert ctl.registry.get(a).state == BlockState.QUEUED
    ctl.expire(filler)                               # holdings drop to 4
    for a in app_ids2:
        assert ctl.registry.get(a).state == BlockState.APPROVED


# --------------------------------------------------- deadline-slack ordering

def test_slack_orders_within_fair_share_class(tmp_path):
    """Equal priority, equal holdings: the tight-deadline latecomer beats
    the loose-deadline (and deadline-less) earlier entries."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    filler = submit_running(ctl, "zed", 8)
    b, _ = ctl.submit("bob", "no deadline", 8)
    c, _ = ctl.submit("carol", "loose", 8, deadline_s=1000.0)
    d, _ = ctl.submit("dave", "tight", 8, deadline_s=5.0)
    order = [e.app_id for e in ctl.scheduler.ordered_waitlist()]
    assert order == [d, c, b]                        # least slack first
    ctl.expire(filler)
    assert ctl.registry.get(d).state == BlockState.APPROVED
    assert ctl.registry.get(c).state == BlockState.QUEUED


def test_deadline_ordering_disabled_restores_fifo(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.scheduler.policy.deadline_ordering = False
    filler = submit_running(ctl, "zed", 8)
    b, _ = ctl.submit("bob", "first", 8, deadline_s=1000.0)
    d, _ = ctl.submit("dave", "tight", 8, deadline_s=5.0)
    order = [e.app_id for e in ctl.scheduler.ordered_waitlist()]
    assert order == [b, d]                           # plain FIFO again


def test_deadline_hit_miss_accounting(tmp_path):
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8)
    hit, _ = ctl.submit("bob", "will hit", 8, deadline_s=3600.0)
    ctl.expire(filler)                               # admitted well in time
    assert ctl.registry.get(hit).state == BlockState.APPROVED
    rep = ctl.monitor.deadline_report()
    assert rep["deadline_hits"] == 1 and rep["deadline_misses"] == 0
    ctl.expire(hit)
    filler2 = submit_running(ctl, "zed", 8)
    miss, _ = ctl.submit("carol", "will miss", 8, deadline_s=0.0)
    assert ctl.registry.get(miss).state == BlockState.QUEUED
    time.sleep(0.01)                                 # deadline passes queued
    ctl.expire(filler2)                              # admitted too late
    assert ctl.registry.get(miss).state == BlockState.APPROVED
    rep = ctl.monitor.deadline_report()
    assert rep["deadline_misses"] == 1
    assert rep["deadline_miss_rate"] == pytest.approx(0.5)
    assert rep["min_admission_slack_s"] < 0


def test_resume_does_not_double_count_deadline_outcome(tmp_path):
    """A preempted block's auto-resume is not a second SLO outcome — the
    job's deadline hit/miss was recorded at first admission."""
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8, priority=5)
    a, _ = ctl.submit("alice", "deadlined", 8, deadline_s=3600.0)
    ctl.expire(filler)                               # admitted in time: hit
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.APPROVED
    ctl.confirm(a, blk.grant.token)
    ctl.registry.set_state(a, BlockState.ACTIVE)
    ctl.registry.set_state(a, BlockState.RUNNING)
    ctl.runtimes[a] = SimRuntime(0.001)
    hi, g = ctl.submit("carol", "urgent", 8, priority=7)   # evicts alice
    assert g is not None
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.expire(hi)                                   # alice auto-resumes
    assert ctl.registry.get(a).state == BlockState.RUNNING
    rep = ctl.monitor.deadline_report()
    assert rep["deadline_hits"] + rep["deadline_misses"] == 1


def test_submit_accepts_model_time(tmp_path):
    """submit(now=...) keeps deadline_at, queued_at and the admission wait
    and slack accounting entirely on the model clock."""
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8, priority=5)
    q, _ = ctl.submit("bob", "queued", 8, deadline_s=50.0, now=100.0)
    blk = ctl.registry.get(q)
    assert blk.deadline_at == 150.0
    assert blk.queued_at == 100.0
    ctl.registry.get(filler).grant.expires_at = 109.0
    ctl.tick(now=110.0)
    assert ctl.registry.get(q).state == BlockState.APPROVED
    assert ctl.monitor.queue_waits[-1] == 10.0
    rep = ctl.monitor.deadline_report()
    assert rep["deadline_hits"] == 1
    assert rep["mean_admission_slack_s"] == pytest.approx(40.0)


def test_deadline_metadata_persisted_for_queued(tmp_path):
    ctl = make_ctl(tmp_path, state=True)
    filler = submit_running(ctl, "zed", 8, priority=5)  # not preemptible
    q, _ = ctl.submit("bob", "queued", 8, priority=2, deadline_s=60.0)
    with open(str(tmp_path / "state.json")) as f:
        snap = json.load(f)
    assert snap[q]["state"] == "queued"
    assert snap[q]["priority"] == 2
    assert snap[q]["deadline_s"] == 60.0
    assert snap[q]["deadline_at"] is not None


# ----------------------------------------------------------- gang admission

def test_gang_admits_immediately_when_everything_fits(tmp_path):
    ctl = make_ctl(tmp_path)                         # 8 chips
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 4), ("eval server", 4)])
    assert grants is not None and len(grants) == 2
    for a in app_ids:
        blk = ctl.registry.get(a)
        assert blk.state == BlockState.APPROVED
        assert blk.request.gang_id == f"gang_{app_ids[0]}"
    ctl.partitioner.check_invariants()


def test_gang_all_or_nothing_waitlists_as_unit(tmp_path):
    """No member is admitted alone, even when one would fit — and the
    failed attempt leaves the inventory bit-identical."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    filler = submit_running(ctl, "zed", 8)           # 8 free
    before = ownership_snapshot(ctl.partitioner)
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 8), ("eval", 4)])      # needs 12 > 8 free
    assert grants is None
    assert ownership_snapshot(ctl.partitioner) == before   # bit-identical
    for a in app_ids:                                # trainer-8 DID fit alone
        assert ctl.registry.get(a).state == BlockState.QUEUED
    ctl.expire(filler)                               # whole pod frees
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.APPROVED
    ctl.partitioner.check_invariants()


def test_allocate_many_rolls_back_on_partial_failure():
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=2))
    part.allocate(4, "filler")
    before = {c: info.owner for c, info in part.chips.items()}
    with pytest.raises(AllocationError):
        part.allocate_many([(2, "g_a", None), (4, "g_b", None)])  # b can't
    after = {c: info.owner for c, info in part.chips.items()}
    assert after == before                           # rollback bit-identical
    part.check_invariants()


def test_can_fit_many_does_not_double_count(tmp_path):
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=2))
    assert part.can_fit_many([(4, None), (4, None)])
    assert not part.can_fit_many([(8, None), (2, None)])  # 10 > 8 chips
    assert not part.can_fit_many([(4, None), (4, None), (4, None)])
    assert all(info.owner is None for info in part.chips.values())


def test_gang_preemption_frees_room_for_whole_gang_or_none(tmp_path):
    """Victim selection uses the gang's full footprint: both low-priority
    blocks are evicted so both gang members co-start."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    lo1 = submit_running(ctl, "alice", 8, priority=0)
    lo2 = submit_running(ctl, "bob", 8, priority=0)
    app_ids, grants = ctl.submit_gang(
        "carol", [("trainer", 8), ("eval", 8)], priority=5)
    assert grants is not None
    assert ctl.registry.get(lo1).state == BlockState.PREEMPTED
    assert ctl.registry.get(lo2).state == BlockState.PREEMPTED
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.APPROVED
    ctl.partitioner.check_invariants()


def test_gang_no_pointless_eviction_when_gang_cannot_fit(tmp_path):
    """If even the full eligible set can't host the gang, nothing is
    evicted (an equal-priority peer blocks part of the footprint)."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    lo = submit_running(ctl, "alice", 8, priority=0)
    peer = submit_running(ctl, "bob", 8, priority=5)  # not evictable
    app_ids, grants = ctl.submit_gang(
        "carol", [("trainer", 8), ("eval", 8)], priority=5)
    assert grants is None
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    assert ctl.registry.get(peer).state == BlockState.RUNNING
    assert ctl.monitor.preemption_report()["preempted_total"] == 0


def test_gang_with_impossible_member_denies_all(tmp_path):
    ctl = make_ctl(tmp_path)                         # 8-chip pod
    app_ids, grants = ctl.submit_gang(
        "alice", [("ok", 4), ("too big", 32)])
    assert grants is None
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.DENIED
    assert ctl.scheduler.queue_depth() == 0


def test_gang_member_denied_while_queued_prunes_gang(tmp_path):
    """Gang atomicity extends to removal: a member denied behind the
    scheduler's back takes its siblings off the waitlist (they could never
    co-start)."""
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8)
    app_ids, grants = ctl.submit_gang("alice", [("a", 4), ("b", 4)])
    assert grants is None
    ctl.registry.deny(app_ids[0], "admin removed gang member")
    ctl.expire(filler)                               # pump must not admit b
    assert ctl.registry.get(app_ids[1]).state == BlockState.DENIED
    assert ctl.scheduler.queue_depth() == 0
    assert ctl.partitioner.free_capacity() == 8      # nothing leaked


def test_gang_boot_failure_terminates_whole_gang(tmp_path, monkeypatch):
    """Co-start is all-or-nothing through boot: if a member's activation
    fails after chips were granted, the whole gang is terminated (chips
    drained + released) instead of left half-running."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    calls = []

    def fake_activate(app_id, job):
        calls.append(app_id)
        if len(calls) == 2:
            raise RuntimeError("device init failed")
        ctl.runtimes[app_id] = SimRuntime(0.001)
        ctl.registry.set_state(app_id, BlockState.ACTIVE, "runtime built")

    monkeypatch.setattr(ctl, "activate", fake_activate)
    with pytest.raises(RuntimeError, match="device init failed"):
        ctl.submit_gang("alice", [("a", 4, object()), ("b", 4, object())])
    assert ctl.partitioner.free_capacity() == 8      # nothing leaked
    for blk in ctl.registry.apps.values():
        assert blk.state == BlockState.EXPIRED       # no half-running gang
    ctl.partitioner.check_invariants()


# --------------------------------------------- lifecycle race / accounting

def test_resize_grows_in_place(tmp_path):
    """Growing 4->8 succeeds when the block's own rectangle plus adjacent
    free chips form a valid 8-rect (previously failed: the search ran while
    the block still owned its old chips)."""
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=2))   # 8 chips
    part.allocate(4, "b0")                           # 2x2 corner
    new = part.resize("b0", 8)                       # whole pod
    assert len(new) == 8
    assert set(part.owned_by("b0")) == set(new)
    part.check_invariants()


def test_resize_failure_keeps_old_chips(tmp_path):
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=2))
    a_coords = part.allocate(4, "a")
    part.allocate(4, "b")
    with pytest.raises(AllocationError):
        part.resize("a", 8)                          # b blocks the 8-rect
    assert set(part.owned_by("a")) == set(a_coords)  # never left empty
    part.check_invariants()


def test_recover_block_defers_when_no_healthy_rectangle(tmp_path):
    """Chip failure with zero spare capacity: the block is checkpointed and
    requeued (PREEMPTED) for auto-resume instead of dying FAILED holding
    nothing — and it resumes once capacity frees."""
    ctl = make_ctl(tmp_path, pod_x=2, pod_y=2)       # 4 chips
    a = submit_running(ctl, "alice", 2)
    b = submit_running(ctl, "bob", 2)                # pod full
    failed_coord = ctl.registry.get(a).grant.coords[0]
    assert ctl.inject_chip_failure(failed_coord) == a
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.PREEMPTED         # deferred, not stuck
    assert ctl.runtimes[a].suspended
    assert blk.preemptions[-1]["from_state"] == "running"
    ctl.partitioner.check_invariants()
    ctl.expire(b)                                    # healthy capacity frees
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.RUNNING           # auto-resumed
    assert failed_coord not in blk.grant.coords      # on healthy chips
    ctl.partitioner.check_invariants()


def test_recover_block_reuses_own_healthy_chips(tmp_path):
    """Allocate-first recovery can re-carve onto the block's own surviving
    chips plus free ones — no release-before-allocate window."""
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=1))
    part.allocate(2, "blk")                          # (0,0),(1,0) columns
    part.allocate(2, "other")
    owned = part.owned_by("blk")
    part.mark_unhealthy(owned[0])
    with pytest.raises(AllocationError):
        part.resize("blk", 2)                        # 1 healthy own + 0 free
    assert set(part.owned_by("blk")) == set(owned)   # untouched on failure


def test_deferred_recovery_of_active_block_stays_active(tmp_path):
    """A block that never started its job (ACTIVE) must not come back
    RUNNING after a deferred chip-failure recovery."""
    ctl = make_ctl(tmp_path, pod_x=2, pod_y=2)       # 4 chips
    a, grant = ctl.submit("alice", "staged", 2)
    ctl.confirm(a, grant.token)
    ctl.registry.set_state(a, BlockState.ACTIVE)
    ctl.runtimes[a] = SimRuntime(0.001)
    b = submit_running(ctl, "bob", 2)                # pod full
    ctl.inject_chip_failure(ctl.registry.get(a).grant.coords[0])
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.PREEMPTED
    assert blk.preemptions[-1]["from_state"] == "active"
    ctl.expire(b)                                    # capacity frees
    assert ctl.registry.get(a).state == BlockState.ACTIVE   # not RUNNING


def test_recovery_success_path_preserves_active_state(tmp_path, monkeypatch):
    """Immediate (non-deferred) chip-failure recovery of an ACTIVE block
    must also return it to ACTIVE, not promote it to RUNNING."""
    import repro.core.controller as controller_mod
    monkeypatch.setattr(controller_mod.BlockRuntime, "rebuild",
                        staticmethod(lambda old, grant, devices, root: old))
    ctl = make_ctl(tmp_path)                         # 8 chips, room to spare
    a, grant = ctl.submit("alice", "staged", 2)
    ctl.confirm(a, grant.token)
    ctl.registry.set_state(a, BlockState.ACTIVE)
    ctl.runtimes[a] = SimRuntime(0.001)
    assert ctl.inject_chip_failure(grant.coords[0]) == a
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.ACTIVE            # recovered, not RUNNING
    assert grant.coords[0] not in blk.grant.coords
    ctl.partitioner.check_invariants()


def test_chip_failure_before_activation_recarves_grant(tmp_path):
    """An APPROVED block owns chips but has no runtime: a chip failure
    re-carves the grant in place instead of crashing on an illegal
    FAILED transition."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    a, grant = ctl.submit("alice", "approved only", 2)
    failed = grant.coords[0]
    assert ctl.inject_chip_failure(failed) == a
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.APPROVED          # lifecycle untouched
    assert failed not in blk.grant.coords            # healthy chips now
    assert blk.grant.token == grant.token            # same capability token
    ctl.partitioner.check_invariants()


def test_chip_failure_before_activation_no_room_terminates_grant(tmp_path):
    ctl = make_ctl(tmp_path, pod_x=2, pod_y=2)       # 4 chips
    a, ga = ctl.submit("alice", "approved", 2)
    b = submit_running(ctl, "bob", 2)                # pod full
    assert ctl.inject_chip_failure(ga.coords[0]) == a
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.EXPIRED           # clean termination
    assert ctl.partitioner.owned_by(ga.block_id) == []
    assert ctl.partitioner.free_capacity() == 1      # the healthy survivor
    ctl.partitioner.check_invariants()


def test_immediate_admission_counts_deadline_hit(tmp_path):
    """Zero-wait admissions are SLO outcomes too — otherwise only queued
    requests would count and the miss rate would be overstated."""
    ctl = make_ctl(tmp_path)
    a, g = ctl.submit("alice", "instant", 8, deadline_s=60.0, now=1000.0)
    assert g is not None
    rep = ctl.monitor.deadline_report()
    assert rep["deadline_hits"] == 1 and rep["deadline_misses"] == 0
    assert rep["mean_admission_slack_s"] == pytest.approx(60.0)


def test_preempt_resume_wait_on_model_clock(tmp_path):
    """Victim requeue time and resume wait stay on the model clock when
    the whole submit/preempt/tick chain is driven with now=..."""
    ctl = make_ctl(tmp_path)
    lo = submit_running(ctl, "alice", 8, priority=0)
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=500.0)
    assert g is not None                             # evicted alice
    assert ctl.registry.get(lo).queued_at == 500.0   # model time, not epoch
    ctl.registry.get(hi).grant.expires_at = 501.0
    ctl.tick(now=510.0)                              # alice auto-resumes
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    assert ctl.monitor.resume_waits[-1] == 10.0


def test_expire_drains_inflight_dispatches(tmp_path):
    """expire() must drain the runtime before releasing its chips — a
    popped runtime with async work in flight would still be executing on
    chips the next pump() hands to another block."""
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 8, step_s=0.05)  # slow SimRuntime
    rt = ctl.runtimes[a]
    rt.dispatch()
    rt.dispatch()
    assert rt.inflight_depth == 2
    b, g = ctl.submit("bob", "next tenant", 8)       # queued behind alice
    assert g is None
    ctl.expire(a)
    assert rt.inflight_depth == 0                    # drained before release
    assert ctl.registry.get(b).state == BlockState.APPROVED


def test_pump_accepts_model_time_zero(tmp_path):
    """pump(now=0.0) must use the given model time, not wall clock."""
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8)
    q, _ = ctl.submit("bob", "queued", 8)
    ctl.registry.get(filler).grant.expires_at = -1.0  # expired at t=0
    ctl.tick(now=0.0)
    assert ctl.registry.get(q).state == BlockState.APPROVED
    # wait recorded relative to model time 0.0, not a huge wall-clock value
    assert ctl.monitor.queue_waits[-1] == 0.0


def test_dead_blocks_accepts_model_time_zero():
    mon = Monitor()
    s = mon._get("blk_x")
    s.steps = 1
    s.last_heartbeat = -30.0                         # model time
    assert mon.dead_blocks(now=0.0) == []            # 30s ago: alive
    s.last_heartbeat = -3600.0
    assert mon.dead_blocks(now=0.0) == ["blk_x"]     # 1h ago: dead


def test_priority_classes_keyed_by_value():
    """With >= 3 priority levels the per-class waits must not collapse into
    a binary high/normal bin."""
    mon = Monitor()
    for prio, wait in [(0, 1.0), (1, 2.0), (1, 4.0), (5, 0.5)]:
        mon.record_enqueue(f"app_p{prio}")
        mon.record_admission(f"app_p{prio}", wait, priority=prio)
    assert set(mon.queue_waits_by_class) == {0, 1, 5}
    rep = mon.preemption_report()
    assert rep["p50_wait_p0_s"] == 1.0
    assert rep["p50_wait_p1_s"] == 3.0               # median of 2.0, 4.0
    assert rep["p50_wait_p5_s"] == 0.5
    assert rep["p50_wait_normal_s"] == 1.0
    assert rep["p50_wait_high_s"] == 2.0             # aggregate of p1 + p5


# ----------------------------------- admission-time completion estimates

def test_completion_estimate_orders_same_deadline_by_remaining_work(tmp_path):
    """Two queued entries with identical deadlines: the one with more
    declared work (est_steps x EWMA step time) has less *effective* slack
    and is admitted first; completion_aware=False restores the tie ->
    FIFO."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    filler = submit_running(ctl, "zed", 8)
    bid = ctl.registry.get(filler).block_id
    ctl.monitor.record_step(bid, step_s=1.0, n_chips=8)  # cluster EWMA 1.0
    c, _ = ctl.submit("carol", "short job", 8, deadline_s=10000.0,
                      est_steps=5)
    b, _ = ctl.submit("bob", "long job", 8, deadline_s=10000.0,
                      est_steps=100)
    order = [e.app_id for e in ctl.scheduler.ordered_waitlist()]
    assert order == [b, c]           # 100 steps of work beats FIFO
    ctl.scheduler.policy.completion_aware = False
    order = [e.app_id for e in ctl.scheduler.ordered_waitlist()]
    assert order == [c, b]           # deadline-only slack ties -> FIFO


def test_completion_estimate_uses_preempted_blocks_own_ewma(tmp_path):
    """A preempted victim's estimate uses its *own* observed EWMA and only
    the steps it has left, not the cluster prior."""
    ctl = make_ctl(tmp_path)
    lo = submit_running(ctl, "alice", 8)
    ctl.registry.get(lo).request.est_steps = 100
    bid = ctl.registry.get(lo).block_id
    for _ in range(10):
        ctl.monitor.record_step(bid, step_s=2.0, n_chips=8)
    hi, g = ctl.submit("eve", "urgent", 8, priority=5)    # evicts alice
    assert g is not None
    entry = ctl.scheduler.waitlist[lo]
    # 100 declared - 10 done = 90 remaining at its own 2.0s EWMA
    assert ctl.scheduler._service_estimate_s(entry) == pytest.approx(180.0)


def test_no_estimate_without_declared_steps_or_history(tmp_path):
    """No est_steps, or no EWMA anywhere yet -> estimate 0.0 (pure
    deadline slack; benchmarks/policy_admission.py results unchanged)."""
    ctl = make_ctl(tmp_path)
    filler = submit_running(ctl, "zed", 8)
    q, _ = ctl.submit("bob", "undeclared", 8, deadline_s=100.0)
    entry = ctl.scheduler.waitlist[q]
    assert ctl.scheduler._service_estimate_s(entry) == 0.0
    ctl.registry.get(q).request.est_steps = 50       # declared, no history
    assert ctl.scheduler._service_estimate_s(entry) == 0.0


# ----------------------------------------------- deadline-aware preemption

def submit_running_deadlined(ctl, user, n_chips, deadline_s, now,
                             priority=0):
    app_id, grant = ctl.submit(user, f"{user} job", n_chips,
                               priority=priority, deadline_s=deadline_s,
                               now=now)
    assert grant is not None, f"{user} did not fit"
    ctl.confirm(app_id, grant.token)
    ctl.registry.set_state(app_id, BlockState.ACTIVE)
    ctl.registry.set_state(app_id, BlockState.RUNNING)
    ctl.runtimes[app_id] = SimRuntime(0.001)
    return app_id


def test_victim_selection_spares_on_track_tight_deadline_block(tmp_path):
    """Two candidate victims; the one on track for a deadline it could no
    longer make after an eviction (headroom < margin) is exempt, so the
    loose-deadline one is evicted instead — even though both otherwise
    rank identically."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    tight = submit_running_deadlined(ctl, "dana", 8, deadline_s=30.0,
                                     now=1000.0)     # headroom 30 < 60
    loose = submit_running_deadlined(ctl, "erin", 8, deadline_s=5000.0,
                                     now=1000.0)
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=1001.0)
    assert g is not None
    assert ctl.registry.get(loose).state == BlockState.PREEMPTED
    assert ctl.registry.get(tight).state == BlockState.RUNNING


def test_no_eviction_when_every_victim_would_newly_miss(tmp_path):
    """All candidates exempt -> the high-priority waiter queues instead of
    pushing an on-track block into a miss it would not have had."""
    ctl = make_ctl(tmp_path)                         # 8 chips
    tight = submit_running_deadlined(ctl, "dana", 8, deadline_s=30.0,
                                     now=1000.0)
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=1001.0)
    assert g is None
    assert ctl.registry.get(hi).state == BlockState.QUEUED
    assert ctl.registry.get(tight).state == BlockState.RUNNING
    assert ctl.monitor.preemption_report()["preempted_total"] == 0


def test_already_missing_victim_is_not_protected(tmp_path):
    """A victim already past its deadline gains no exemption — eviction
    creates no *new* miss."""
    ctl = make_ctl(tmp_path)
    late = submit_running_deadlined(ctl, "dana", 8, deadline_s=5.0,
                                    now=1000.0)      # misses at t=1005
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=2000.0)
    assert g is not None
    assert ctl.registry.get(late).state == BlockState.PREEMPTED


def test_exemption_accounts_for_estimated_remaining_work(tmp_path):
    """A distant deadline still exempts the victim when its declared
    remaining work eats the slack (headroom = slack - est remaining)."""
    ctl = make_ctl(tmp_path)
    v = submit_running_deadlined(ctl, "dana", 8, deadline_s=500.0,
                                 now=1000.0)
    blk = ctl.registry.get(v)
    blk.request.est_steps = 120                      # 120 x 4.0s = 480s
    for _ in range(5):
        ctl.monitor.record_step(blk.block_id, step_s=4.0, n_chips=8)
    # headroom at t=1001: 499 - (115 remaining x 4.0 = 460) = 39 < 60
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=1001.0)
    assert g is None
    assert ctl.registry.get(v).state == BlockState.RUNNING


def test_deadline_aware_preemption_can_be_disabled(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.scheduler.policy.deadline_aware_preemption = False
    tight = submit_running_deadlined(ctl, "dana", 8, deadline_s=30.0,
                                     now=1000.0)
    hi, g = ctl.submit("carol", "urgent", 8, priority=5, now=1001.0)
    assert g is not None                             # old behavior
    assert ctl.registry.get(tight).state == BlockState.PREEMPTED


# ------------------------------------------------------ gang resume re-gang

def test_preempted_gang_resumes_as_one_unit(tmp_path):
    """An evicted gang re-enters the waitlist as a unit: it never resumes
    into capacity that fits only one member, and co-resumes the moment the
    whole footprint fits."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 4), ("eval", 4)])
    assert grants is not None
    for a in app_ids:
        ctl.confirm(a, grants[a].token)
        ctl.registry.set_state(a, BlockState.ACTIVE)
        ctl.registry.set_state(a, BlockState.RUNNING)
        ctl.runtimes[a] = SimRuntime(0.001)
    bob = submit_running(ctl, "bob", 4)
    dave = submit_running(ctl, "dave", 4)            # pod full
    hi, g = ctl.submit("carol", "urgent", 8, priority=5)
    assert g is not None
    # cheapest sufficient set = the two 4-chip gang members
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.expire(bob)                                  # 4 free: half the gang
    for a in app_ids:                                # no solo resume
        assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.expire(dave)                                 # 8 free: whole gang
    for a in app_ids:
        assert ctl.registry.get(a).state == BlockState.RUNNING
    ctl.partitioner.check_invariants()


def test_single_evicted_gang_member_resumes_alone(tmp_path):
    """Co-resume binds the *evicted subset*: when only one member was
    preempted (siblings kept running), it resumes by itself."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)       # 16 chips
    app_ids, grants = ctl.submit_gang(
        "alice", [("trainer", 4), ("eval", 8)])
    assert grants is not None
    for a in app_ids:
        ctl.confirm(a, grants[a].token)
        ctl.registry.set_state(a, BlockState.ACTIVE)
        ctl.registry.set_state(a, BlockState.RUNNING)
        ctl.runtimes[a] = SimRuntime(0.001)
    trainer, eval_srv = app_ids
    bob = submit_running(ctl, "bob", 4)              # pod full
    ctl.runtimes[bob].step_count = 5                 # pricier to stop
    hi, g = ctl.submit("carol", "urgent", 4, priority=5)
    assert g is not None                             # evicts the trainer
    assert ctl.registry.get(trainer).state == BlockState.PREEMPTED
    assert ctl.registry.get(eval_srv).state == BlockState.RUNNING
    ctl.expire(hi)                                   # 4 free again
    assert ctl.registry.get(trainer).state == BlockState.RUNNING
    ctl.partitioner.check_invariants()


def test_policy_quota_defaults_uncapped():
    pol = SchedulingPolicy()
    assert pol.admission_blocked("anyone", 10 ** 6, 10 ** 6, 10.0 ** 12) \
        is None
    assert not pol.over_quota("anyone", 10 ** 6, 10.0 ** 12)
    pol.default_quota = UserQuota(max_chips=8)
    assert pol.admission_blocked("anyone", 4, 8, 0.0) is not None
