"""Tests for the concurrency & lifecycle verifier (repro.analysis).

Three layers:

* **CLI/gate** — the analyzer exits 0 on the real repo (empty baseline: the
  tree is clean, nothing grandfathered) and non-zero on the seeded-violation
  corpus in tests/fixtures/, with every seeded rule class firing.
* **Lifecycle properties** — hypothesis-style fuzz (via the seeded compat
  shim) of the TRANSITIONS table: every non-terminal state reaches a
  terminal, random legal walks never raise, and a registry snapshot
  round-trips every state value.
* **Runtime detector units** — the lock-order recorder, self-deadlock
  check and serialized-section ownership assertions, each against a
  *private* Recorder so deliberately-seeded violations never pollute the
  session-wide REPRO_RACE_CHECK gate.
"""
import json
import os
import threading

import pytest

from repro.analysis import analyze_paths, load_baseline
from repro.analysis.__main__ import main as analysis_main
from repro.analysis import runtime_check
from repro.core.block import (Block, BlockGrant, BlockRequest, BlockState,
                              TRANSITIONS)
from repro.core.registry import Registry

from tests._hypothesis_compat import given, settings, st

_HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(_HERE, "fixtures")
SRC_REPRO = os.path.normpath(os.path.join(_HERE, "..", "src", "repro"))

TERMINAL = {s for s in BlockState if s not in TRANSITIONS}


# --------------------------------------------------------------- CLI / gate
def test_repo_is_clean_with_empty_baseline():
    """The tree itself must carry zero error findings — nothing was
    grandfathered into the baseline."""
    baseline_path = os.path.join(SRC_REPRO, "analysis", "baseline.json")
    assert load_baseline(baseline_path) == []
    assert analysis_main([SRC_REPRO]) == 0


def test_fixtures_fail_the_gate():
    assert analysis_main([FIXTURES, "--no-baseline"]) == 1


def test_every_seeded_rule_fires():
    report, _model = analyze_paths([FIXTURES])
    rules = {f.rule for f in report.errors()}
    assert rules >= {
        "lock-order-cycle",          # seeded_lock_cycle.py
        "lock-discipline",           # seeded_lock_discipline.py
        "lock-self-deadlock",        # seeded_lock_discipline.py
        "state-assign-bypass",       # seeded_lifecycle.py
        "illegal-transition-target",  # seeded_lifecycle.py
        "illegal-transition-edge",   # seeded_lifecycle.py
        "unknown-event-kind",        # seeded_events.py
        "falsy-zero-param",          # seeded_falsy_now.py
    }


def test_seeded_findings_point_at_the_seeds():
    report, _ = analyze_paths([FIXTURES])
    by_rule = {}
    for f in report.errors():
        by_rule.setdefault(f.rule, set()).add(os.path.basename(f.path))
    assert by_rule["lock-order-cycle"] == {"seeded_lock_cycle.py"}
    assert by_rule["lock-discipline"] == {"seeded_lock_discipline.py"}
    assert by_rule["state-assign-bypass"] == {"seeded_lifecycle.py"}
    assert by_rule["unknown-event-kind"] == {"seeded_events.py"}
    assert by_rule["falsy-zero-param"] == {"seeded_falsy_now.py"}


def test_unknown_event_kind_covers_all_three_sides():
    """publish literal, kinds= filter, and ev.kind comparison each fire."""
    report, _ = analyze_paths([FIXTURES])
    symbols = {f.symbol for f in report.errors()
               if f.rule == "unknown-event-kind"}
    assert "publish:block_rebooted" in symbols
    assert "subscribe:kinds:rebooted" in symbols
    assert any(s.endswith("kind==warp") for s in symbols)


def test_baseline_suppresses_known_findings_by_fingerprint():
    """A baselined finding stays suppressed when its line number moves —
    fingerprints are (rule, path, symbol), not line-keyed."""
    report, _ = analyze_paths([FIXTURES])
    errors = report.errors()
    assert errors
    baseline = [f.fingerprint() for f in errors]
    assert report.new_findings(baseline) == []
    # dropping one baseline entry re-exposes exactly that finding
    assert len(report.new_findings(baseline[1:])) == 1


def test_cli_json_output(tmp_path):
    out = tmp_path / "findings.json"
    assert analysis_main([FIXTURES, "--no-baseline",
                          "--json", str(out)]) == 1
    data = json.loads(out.read_text())
    assert {f["rule"] for f in data["findings"]} >= {"lock-order-cycle"}
    assert "edges" in data["model"]["locks"]
    assert "transitions" in data["model"]["lifecycle"]


def test_describe_reports_learned_model(capsys):
    assert analysis_main([SRC_REPRO, "--describe"]) == 0
    model = json.loads(capsys.readouterr().out)
    # the daemon serial lock must order before the registry lock, and the
    # registry lock before the event bus (publish happens under _lock)
    assert "ClusterDaemon._serial -> Registry._lock" in model["locks"]["edges"]
    assert "Registry._lock -> EventBus._lock" in model["locks"]["edges"]
    assert model["lifecycle"]["terminal"] == ["DENIED", "EXPIRED"]
    assert set(model["events"]["kinds"]) == {
        "registered", "state", "enqueued", "dequeued", "admitted",
        "preempted", "resumed", "step", "compile", "utilization",
        "autostep", "session", "generate", "pod", "migrated",
        "postmortem"}


# ------------------------------------------------------ lifecycle properties
def test_every_nonterminal_reaches_terminal():
    """TRANSITIONS closure: BFS from every state hits DENIED or EXPIRED."""
    for start in BlockState:
        seen, frontier = {start}, [start]
        while frontier:
            nxt = []
            for s in frontier:
                for t in TRANSITIONS.get(s, ()):
                    if t not in seen:
                        seen.add(t)
                        nxt.append(t)
            frontier = nxt
        assert seen & TERMINAL, f"{start} cannot reach a terminal state"


def test_terminal_states_have_no_exit():
    for s in TERMINAL:
        assert not TRANSITIONS.get(s), f"terminal {s} has outgoing edges"


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10 ** 6), min_size=0, max_size=12))
def test_random_legal_walks_never_raise(choices):
    """Following any legal path from REQUESTED via Block.transition raises
    nothing and every intermediate state stays in the declared set."""
    blk = Block(request=BlockRequest(user="fuzz", job_description="walk",
                                     n_chips=4))
    assert blk.state is BlockState.REQUESTED
    for c in choices:
        targets = sorted(TRANSITIONS.get(blk.state, ()), key=lambda s: s.name)
        if not targets:
            break
        blk.transition(targets[c % len(targets)], "fuzz step")
        assert blk.state in set(BlockState)
    # history logged one entry per transition
    assert len(blk.history) == sum(
        1 for h in blk.history if isinstance(h, tuple))


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(sorted(BlockState, key=lambda s: s.name)))
def test_registry_snapshot_roundtrips_every_state(tmp_path_factory, state):
    """A block persisted in any lifecycle state reads back as exactly that
    state from the JSON snapshot (the external UI's view)."""
    path = str(tmp_path_factory.mktemp("reg") / "registry.json")
    reg = Registry(state_path=path)
    app_id = reg.register(BlockRequest(user="u", job_description="j",
                                       n_chips=2))
    blk = reg.get(app_id)
    blk.grant = BlockGrant.new([(0, 0)], (1, 1), 60.0)
    blk.state = state            # test-only bypass: pin the exact state
    reg.persist()
    with open(path) as f:
        snap = json.load(f)
    assert snap[app_id]["state"] == state.value
    assert BlockState(snap[app_id]["state"]) is state


def test_illegal_transition_raises_and_preserves_state():
    blk = Block(request=BlockRequest(user="u", job_description="j",
                                     n_chips=1))
    with pytest.raises(ValueError, match="illegal transition"):
        blk.transition(BlockState.RUNNING, "skip the queue")
    assert blk.state is BlockState.REQUESTED


# ------------------------------------------------- runtime detector (units)
def test_lock_order_inversion_detected():
    rec = runtime_check.Recorder()
    a = runtime_check.make_lock("A", recorder=rec)
    b = runtime_check.make_lock("B", recorder=rec)
    with a:
        with b:                      # order A -> B
            pass
    with b:
        with a:                      # order B -> A: closes the cycle
            pass
    vs = rec.snapshot()
    assert len(vs) == 1 and "lock-order inversion" in vs[0]
    assert "A" in vs[0] and "B" in vs[0]


def test_consistent_order_is_clean():
    rec = runtime_check.Recorder()
    a = runtime_check.make_lock("A", recorder=rec)
    b = runtime_check.make_lock("B", recorder=rec)
    for _ in range(3):
        with a:
            with b:
                pass
    assert rec.snapshot() == []
    assert rec.order_edges() == ["A -> B"]


def test_self_deadlock_detected_without_blocking():
    rec = runtime_check.Recorder()
    a = runtime_check.make_lock("A", reentrant=False, recorder=rec)
    assert a.acquire()
    assert a.acquire(False) is False     # real lock refuses; no hang
    a.release()
    vs = rec.snapshot()
    assert len(vs) == 1 and "self-deadlock" in vs[0]


def test_reentrant_lock_reacquire_is_clean():
    rec = runtime_check.Recorder()
    r = runtime_check.make_lock("R", reentrant=True, recorder=rec)
    with r:
        with r:
            pass
    assert rec.snapshot() == []


def test_serialized_section_cross_thread_violation():
    rec = runtime_check.Recorder()
    outer = runtime_check.serialized("control-plane", recorder=rec)
    outer.__enter__()                    # main thread owns the section
    try:
        def intruder():
            with runtime_check.serialized("control-plane", recorder=rec):
                pass
        t = threading.Thread(target=intruder)
        t.start()
        t.join()
    finally:
        outer.__exit__(None, None, None)
    vs = rec.snapshot()
    assert len(vs) == 1 and "serialized-section violation" in vs[0]


def test_serialized_section_same_thread_nesting_is_clean():
    rec = runtime_check.Recorder()
    with runtime_check.serialized("control-plane", recorder=rec):
        with runtime_check.serialized("control-plane", recorder=rec):
            pass
    with runtime_check.serialized("control-plane", recorder=rec):
        pass
    assert rec.snapshot() == []


def test_serialized_noop_when_not_installed():
    """Outside REPRO_RACE_CHECK runs the guard must be free and inert."""
    if runtime_check.installed():
        pytest.skip("race check installed for this session")
    ctx = runtime_check.serialized("control-plane")
    with ctx:
        pass
    assert ctx is runtime_check.serialized("anything-else")


def test_condition_works_with_instrumented_lock():
    """threading.Condition over an instrumented lock: wait/notify cycle."""
    rec = runtime_check.Recorder()
    lk = runtime_check.make_lock("C", reentrant=True, recorder=rec)
    cond = threading.Condition(lk)
    hits = []

    def waiter():
        with cond:
            while not hits:
                cond.wait(timeout=5.0)
            hits.append("woke")

    t = threading.Thread(target=waiter)
    t.start()
    with cond:
        hits.append("set")
        cond.notify_all()
    t.join(timeout=5.0)
    assert not t.is_alive() and hits == ["set", "woke"]
    assert rec.snapshot() == []
