"""Checkpoint-backed preemption: lifecycle transitions, victim selection,
no-churn guard, waitlist re-entry ahead of the fair-share class, auto-resume
via tick(), bit-identical suspend->resume on the real BlockRuntime, and
resume onto a different chip set / mesh geometry (subprocess, multi-device).
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import pytest

from repro.core.block import BlockState
from repro.core.controller import ClusterController
from repro.core.partition import AllocationError, Partitioner
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def make_ctl(tmp_path, pod_x=4, pod_y=2, n_pods=1):
    topo = Topology(n_pods=n_pods, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterController(topo, devices=[dev] * topo.n_chips,
                             ckpt_root=str(tmp_path / "ckpt"),
                             state_path=str(tmp_path / "state.json"))


def submit_running(ctl, user, n_chips, priority=0, step_s=0.001,
                   ckpt_every=0, pod=None):
    """Admit a block and fake it into RUNNING with a SimRuntime."""
    app_id, grant = ctl.submit(user, f"{user} job", n_chips,
                               priority=priority, pod=pod)
    assert grant is not None, f"{user} did not fit"
    ctl.confirm(app_id, grant.token)
    ctl.registry.set_state(app_id, BlockState.ACTIVE)
    ctl.registry.set_state(app_id, BlockState.RUNNING)
    ctl.runtimes[app_id] = SimRuntime(step_s, ckpt_every=ckpt_every)
    return app_id


# ----------------------------------------------------------- state machine

def test_preempted_transitions(tmp_path):
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 8)
    ctl.preempt(a, "test eviction")
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.PREEMPTED
    assert blk.preempt_count == 1
    assert blk.preemptions[0]["reason"] == "test eviction"
    # PREEMPTED -> RUNNING directly is illegal; resume goes via ACTIVE
    with pytest.raises(ValueError):
        blk.transition(BlockState.RUNNING)
    ctl.resume(a)
    assert blk.state == BlockState.RUNNING


def test_preemption_history_is_persisted(tmp_path):
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 8)
    ctl.runtimes[a].step_count = 7          # unsaved progress
    ctl.preempt(a, "for the test")
    with open(str(tmp_path / "state.json")) as f:
        snap = json.load(f)
    assert snap[a]["state"] == "preempted"
    assert snap[a]["preempt_count"] == 1
    assert snap[a]["preemptions"][0]["progress_lost_steps"] == 7
    assert snap[a]["preemptions"][0]["checkpoint_step"] == 7  # saved on drain


def test_preempted_block_expires_without_resume(tmp_path):
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 8)
    ctl.preempt(a, "evicted")
    ctl.registry.get(a).grant.expires_at = time.time() - 1
    assert ctl.tick() == [a]                # period ends while suspended
    assert ctl.registry.get(a).state == BlockState.EXPIRED
    assert ctl.scheduler.queue_depth() == 0
    assert ctl.partitioner.free_capacity() == 8


# ------------------------------------------------------------- scheduling

def test_high_priority_preempts_running_block(tmp_path):
    ctl = make_ctl(tmp_path)                # 8 chips
    lo = submit_running(ctl, "alice", 8, priority=0)
    hi, grant = ctl.submit("carol", "urgent", 8, priority=5)
    assert grant is not None                # admitted immediately via eviction
    assert ctl.registry.get(lo).state == BlockState.PREEMPTED
    assert ctl.runtimes[lo].suspended
    rep = ctl.monitor.preemption_report()
    assert rep["preempted_total"] == 1
    ctl.partitioner.check_invariants()


def test_victim_selection_ordering(tmp_path):
    """Victim = (lowest priority, least progress since checkpoint, fewest
    chips) among blocks whose chips let the waiter fit."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)   # 16 chips
    a = submit_running(ctl, "alice", 4, priority=1)
    b = submit_running(ctl, "bob", 4, priority=0)
    c = submit_running(ctl, "carol", 4, priority=0)
    d = submit_running(ctl, "dan", 4, priority=0)
    ctl.runtimes[b].step_count = 9          # bob would lose 9 steps
    ctl.runtimes[c].step_count = 2          # carol would lose 2 -> victim
    ctl.runtimes[d].step_count = 5          # dan would lose 5
    hi, grant = ctl.submit("eve", "urgent", 4, priority=5)
    assert grant is not None
    assert ctl.registry.get(c).state == BlockState.PREEMPTED
    for other in (a, b, d):
        assert ctl.registry.get(other).state == BlockState.RUNNING


def test_no_churn_equal_priority_never_preempts(tmp_path):
    """The no-churn guard: a waiter can only evict *strictly* lower
    priority, so two equal-priority blocks can't displace each other in a
    loop."""
    ctl = make_ctl(tmp_path)
    lo = submit_running(ctl, "alice", 8, priority=3)
    hi, grant = ctl.submit("bob", "same prio", 8, priority=3)
    assert grant is None                    # queued, no eviction
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    assert ctl.registry.get(hi).state == BlockState.QUEUED
    ctl.tick()                              # still no churn on later ticks
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    # and the preempted victim of a real eviction can't re-evict its evictor
    hi2, grant2 = ctl.submit("carol", "urgent", 8, priority=5)
    assert grant2 is not None
    assert ctl.registry.get(lo).state == BlockState.PREEMPTED
    ctl.tick()
    assert ctl.registry.get(hi2).state in (BlockState.APPROVED,)
    assert ctl.registry.get(lo).state == BlockState.PREEMPTED
    assert ctl.monitor.preemption_report()["preempted_total"] == 1


def test_preempted_reenters_ahead_of_fair_share_class(tmp_path):
    """On resume eligibility, an evicted block outranks same-priority
    QUEUED entries regardless of chips its user already holds."""
    ctl = make_ctl(tmp_path)                # 8 chips
    lo = submit_running(ctl, "alice", 8, priority=0)
    # bob queues first (would normally win FIFO + holds 0 chips)
    b, g = ctl.submit("bob", "waiting", 8, priority=0)
    assert g is None
    hi, g2 = ctl.submit("carol", "urgent", 8, priority=5)
    assert g2 is not None                   # evicts alice
    order = [e.app_id for e in ctl.scheduler.ordered_waitlist()]
    assert order == [lo, b]                 # victim ahead of bob
    ctl.expire(hi)                          # capacity frees -> pump
    assert ctl.registry.get(lo).state == BlockState.RUNNING  # resumed first
    assert ctl.registry.get(b).state == BlockState.QUEUED


def test_tick_auto_resumes_when_capacity_frees(tmp_path):
    ctl = make_ctl(tmp_path)
    lo = submit_running(ctl, "alice", 8, priority=0, ckpt_every=2)
    ctl.step_all(rounds=5)
    hi, grant = ctl.submit("carol", "urgent", 8, priority=5)
    assert grant is not None
    steps_at_suspend = ctl.runtimes[lo].step_count
    ctl.registry.get(hi).grant.expires_at = time.time() - 1
    ctl.tick()                              # expire carol + auto-resume alice
    blk = ctl.registry.get(lo)
    assert blk.state == BlockState.RUNNING
    assert ctl.runtimes[lo].step_count == steps_at_suspend
    ctl.step_all(rounds=2)
    assert ctl.runtimes[lo].step_count == steps_at_suspend + 2
    rep = ctl.monitor.preemption_report()
    assert rep["resumed_total"] == 1
    assert rep["mean_resume_wait_s"] >= 0.0


def test_preemption_disabled_keeps_old_behavior(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.scheduler.preemption_enabled = False
    lo = submit_running(ctl, "alice", 8, priority=0)
    hi, grant = ctl.submit("carol", "urgent", 8, priority=5)
    assert grant is None                    # waits like PR-1 semantics
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    assert ctl.registry.get(hi).state == BlockState.QUEUED


def test_partial_eviction_multi_block(tmp_path):
    """The waiter only needs one victim's rectangle: the smallest
    sufficient lower-priority block is evicted, others keep running."""
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=4)   # 16 chips
    big = submit_running(ctl, "alice", 8, priority=0)
    small = submit_running(ctl, "bob", 8, priority=0)
    ctl.runtimes[big].step_count = 1
    ctl.runtimes[small].step_count = 1
    hi, grant = ctl.submit("carol", "urgent", 4, priority=2)
    assert grant is not None
    preempted = [a for a in (big, small)
                 if ctl.registry.get(a).state == BlockState.PREEMPTED]
    assert len(preempted) == 1              # one victim suffices for 4 chips
    ctl.partitioner.check_invariants()


def test_multi_victim_eviction_when_one_is_not_enough(tmp_path):
    """A waiter whose footprint spans several smaller blocks evicts the
    cheapest sufficient *set* instead of starving until expiry."""
    ctl = make_ctl(tmp_path)                # 8 chips
    a = submit_running(ctl, "alice", 4, priority=0)
    b = submit_running(ctl, "bob", 4, priority=0)
    hi, grant = ctl.submit("carol", "urgent full pod", 8, priority=5)
    assert grant is not None
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    assert ctl.registry.get(b).state == BlockState.PREEMPTED
    assert ctl.monitor.preemption_report()["preempted_total"] == 2
    ctl.expire(hi)                          # both victims auto-resume
    assert ctl.registry.get(a).state == BlockState.RUNNING
    assert ctl.registry.get(b).state == BlockState.RUNNING
    ctl.partitioner.check_invariants()


def test_no_pointless_eviction_when_set_still_insufficient(tmp_path):
    """If even evicting every eligible block can't fit the waiter, nothing
    is evicted (e.g. part of the pod is held by equal-priority blocks)."""
    ctl = make_ctl(tmp_path)                # 8 chips
    lo = submit_running(ctl, "alice", 4, priority=0)
    peer = submit_running(ctl, "bob", 4, priority=5)   # not evictable
    hi, grant = ctl.submit("carol", "urgent full pod", 8, priority=5)
    assert grant is None
    assert ctl.registry.get(lo).state == BlockState.RUNNING
    assert ctl.registry.get(peer).state == BlockState.RUNNING
    assert ctl.monitor.preemption_report()["preempted_total"] == 0


def test_victim_set_is_pruned_to_contributing_blocks(tmp_path):
    """The greedy multi-victim prefix can pick up a cheap victim whose
    chips don't actually help the waiter fit; pruning must spare it."""
    ctl = make_ctl(tmp_path, pod_x=6, pod_y=2)   # 12 chips
    a = submit_running(ctl, "alice", 4)          # 2x2 at x0
    b = submit_running(ctl, "bob", 2)            # 1x2 at x2
    c = submit_running(ctl, "carol", 4)          # 2x2 at x3; 1x2 free at x5
    ctl.runtimes[a].step_count = 0               # cheapest victim by rank...
    ctl.runtimes[b].step_count = 1
    ctl.runtimes[c].step_count = 2
    hi, grant = ctl.submit("dave", "urgent", 8, priority=5)  # needs 4x2
    assert grant is not None
    # ...but evicting bob+carol alone frees a 4x2 rectangle: alice survives
    assert ctl.registry.get(a).state == BlockState.RUNNING
    assert ctl.registry.get(b).state == BlockState.PREEMPTED
    assert ctl.registry.get(c).state == BlockState.PREEMPTED
    ctl.partitioner.check_invariants()


def test_pod_pinning_survives_preempt_resume(tmp_path):
    """A block pinned to a pod at submission must not silently migrate to
    another pod on auto-resume."""
    ctl = make_ctl(tmp_path, pod_x=2, pod_y=2, n_pods=2)
    a = submit_running(ctl, "alice", 4, pod=0)
    d = submit_running(ctl, "dave", 4, pod=1)
    assert all(c[0] == 0 for c in ctl.registry.get(a).grant.coords)
    hi, g = ctl.submit("carol", "urgent", 4, priority=5, pod=0)
    assert g is not None                        # evicts alice from pod 0
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.expire(d)                # pod 1 frees, but alice is pinned to pod 0
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.expire(hi)                              # pod 0 frees -> resume there
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.RUNNING
    assert all(c[0] == 0 for c in blk.grant.coords)


def test_resume_returns_to_pre_preemption_state(tmp_path):
    """A victim that was only ACTIVE (job never started) must not come
    back RUNNING after auto-resume."""
    ctl = make_ctl(tmp_path)
    app_id, grant = ctl.submit("alice", "staged", 8)
    ctl.confirm(app_id, grant.token)
    ctl.registry.set_state(app_id, BlockState.ACTIVE)
    ctl.runtimes[app_id] = SimRuntime(0.001)
    ctl.preempt(app_id, "evicted while staged")
    assert ctl.registry.get(app_id).preemptions[-1]["from_state"] == "active"
    ctl.tick()
    assert ctl.registry.get(app_id).state == BlockState.ACTIVE  # not RUNNING


def test_priority_override_is_persisted(tmp_path):
    """submit(priority=N) must stick on the request: victim selection and
    requeue read request.priority, and a mismatch would let an evicted
    lower-priority block bounce its evictor right back out."""
    ctl = make_ctl(tmp_path)
    app_id = ctl.register("alice", "j", 8)       # request.priority == 0
    ctl.scheduler.submit(app_id, priority=7)
    assert ctl.registry.get(app_id).request.priority == 7


def test_preempt_invalid_state_raises_without_mutation(tmp_path):
    """preempt() of a non-running block must fail *before* suspending the
    runtime or releasing chips."""
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 8)
    ctl.registry.set_state(a, BlockState.DONE, "finished")
    held_before = ctl.partitioner.free_capacity()
    with pytest.raises(ValueError, match="cannot preempt"):
        ctl.preempt(a, "too late")
    assert ctl.partitioner.free_capacity() == held_before   # nothing released
    assert not ctl.runtimes[a].suspended
    assert ctl.registry.get(a).state == BlockState.DONE


def test_can_fit_excluding_restores_inventory():
    part = Partitioner(Topology(n_pods=1, pod_x=2, pod_y=2))
    coords = part.allocate(4, "blk_a")
    assert not part.can_fit(2)
    assert part.can_fit_excluding(2, ["blk_a"])
    assert part.can_fit_excluding(4, ["blk_a"])
    assert not part.can_fit_excluding(2, ["blk_other"])
    # dry-run left ownership untouched
    assert all(part.owner_of(c) == "blk_a" for c in coords)
    with pytest.raises(AllocationError):
        part.allocate(2, "blk_b")


# ----------------------------------------------- real-runtime round trips

@pytest.mark.slow
def test_suspend_resume_bit_identical_params(tmp_path):
    """Preempt->resume restores bit-identical state on the real runtime."""
    import numpy as np
    import repro.configs as C
    from repro.core.runtime import JobSpec
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig

    ctl = make_ctl(tmp_path, pod_x=2, pod_y=1)
    shape = ShapeConfig("t", "train", seq_len=16, global_batch=2,
                        microbatch=1)
    job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                  opt=OptConfig(warmup_steps=1, total_steps=8))
    a, g = ctl.submit("alice", "train", 1, job=job)
    ctl.step_all(rounds=3)
    rt = ctl.runtimes[a]
    before = [np.asarray(l) for l in jax.tree.leaves(rt.state)]
    steps_before = rt.step_count

    ctl.preempt(a, "bit-identity test")
    assert rt.suspended and rt.state is None
    assert ctl.partitioner.free_capacity() == 2     # chips released
    ctl.tick()                                      # auto-resume
    assert ctl.registry.get(a).state == BlockState.RUNNING
    assert rt.step_count == steps_before
    after = [np.asarray(l) for l in jax.tree.leaves(rt.state)]
    assert len(before) == len(after)
    for x, y in zip(before, after):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()           # bitwise
    ctl.step_all(rounds=1)
    assert rt.step_count == steps_before + 1


@pytest.mark.slow
def test_resume_is_a_compile_cache_hit(tmp_path):
    """Resuming on the same chips must NOT recompile: the rebuilt runtime's
    train step comes out of the compile cache (the first attach was the only
    miss for that signature), and the Monitor counts the hit."""
    import repro.configs as C
    from repro.core.runtime import JobSpec
    from repro.models.config import ShapeConfig
    from repro.train import compile_cache
    from repro.train.optimizer import OptConfig

    compile_cache.GLOBAL.clear()            # process-wide: isolate the test
    ctl = make_ctl(tmp_path, pod_x=2, pod_y=1)
    shape = ShapeConfig("t", "train", seq_len=16, global_batch=2,
                        microbatch=1)
    job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                  opt=OptConfig(warmup_steps=1, total_steps=8))
    a, g = ctl.submit("alice", "train", 1, job=job)
    ctl.step_all(rounds=2)
    first = compile_cache.GLOBAL.stats()
    assert first["misses"] >= 1             # initial attach built the step
    assert first["hits"] == 0

    ctl.preempt(a, "compile-cache test")
    ctl.tick()                              # auto-resume on the same chips
    assert ctl.registry.get(a).state == BlockState.RUNNING
    after = compile_cache.GLOBAL.stats()
    assert after["misses"] == first["misses"], "resume recompiled the step"
    assert after["hits"] >= 1
    ctl.step_all(rounds=1)                  # reused wrapper still steps

    # the bus carried the events and the Monitor translated them
    evs = ctl.bus.events_since(kinds={"compile"})
    actions = [e.payload["action"] for e in evs]
    assert "miss" in actions and "hit" in actions
    rep = ctl.monitor.compile_report()
    assert rep["compile_hits_total"] == after["hits"]
    assert rep["compile_misses_total"] == after["misses"]
    assert rep["compile_hit_rate"] > 0

    # the activation also attached the block's roofline model, so the
    # step-time EWMA reads back as achieved-vs-peak utilization
    blk = ctl.registry.get(a)
    assert ctl.monitor.mfu(blk.block_id) is not None
    roof = ctl.monitor.roofline_report()
    assert blk.block_id in roof["blocks"] and roof["mean_mfu"] > 0


@pytest.mark.slow
def test_serve_block_suspend_resume_keeps_decode_context(tmp_path):
    """A serve block's KV cache / token / cache_len survive preemption —
    without them a restored decoder would silently restart from an empty
    cache at position 0."""
    import numpy as np
    import repro.configs as C
    from repro.core.runtime import JobSpec
    from repro.models.config import ShapeConfig

    ctl = make_ctl(tmp_path, pod_x=2, pod_y=1)
    shape = ShapeConfig("s", "serve", seq_len=16, global_batch=2,
                        microbatch=1)
    job = JobSpec(C.get_smoke("xlstm_350m"), shape, kind="serve")
    a, g = ctl.submit("alice", "serve", 1, job=job)
    ctl.step_all(rounds=3)                  # decode 3 tokens
    rt = ctl.runtimes[a]
    rt.drain()
    cache_before = [np.asarray(l) for l in jax.tree.leaves(rt.cache)]
    token_before = np.asarray(rt.token)
    len_before = int(rt.cache_len)
    assert len_before == 3

    ctl.preempt(a, "serve context test")
    assert rt.cache is None and rt.token is None
    ctl.tick()                              # auto-resume
    assert ctl.registry.get(a).state == BlockState.RUNNING
    assert int(rt.cache_len) == len_before
    assert np.asarray(rt.token).tobytes() == token_before.tobytes()
    cache_after = [np.asarray(l) for l in jax.tree.leaves(rt.cache)]
    assert len(cache_before) == len(cache_after)
    for x, y in zip(cache_before, cache_after):
        assert x.tobytes() == y.tobytes()
    ctl.step_all(rounds=1)                  # decoding continues
    assert int(rt.cache_len) == len_before + 1


@pytest.mark.slow
def test_resume_on_different_geometry(tmp_path):
    """Suspend on a (2,2) 4-chip mesh, resume on (2,1) 2 chips — the
    checkpoint manager reshards host leaves onto the new mesh; params stay
    bit-identical.  Needs >1 device, so runs in a subprocess."""
    code = f"""
    import jax, numpy as np
    import repro.configs as C
    from repro.core.block import BlockState
    from repro.core.controller import ClusterController
    from repro.core.runtime import JobSpec
    from repro.core.topology import Topology
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig

    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    ctl = ClusterController(topo, ckpt_root={str(tmp_path)!r})
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=4,
                        microbatch=2)
    job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                  opt=OptConfig(warmup_steps=1, total_steps=8))
    a, g = ctl.submit("alice", "train", 4, job=job)
    assert g.mesh_shape == (2, 2), g.mesh_shape
    ctl.step_all(rounds=2)
    rt = ctl.runtimes[a]
    before = [np.asarray(l) for l in jax.tree.leaves(rt.state)]

    ctl.preempt(a, "geometry test")
    grant = ctl.resume(a, n_chips=2)          # resume at half size
    assert grant.mesh_shape in ((1, 2), (2, 1)), grant.mesh_shape
    assert grant.block_id == g.block_id
    assert tuple(rt.mesh.devices.shape) == grant.mesh_shape
    assert rt.step_count == 2
    after = [np.asarray(l) for l in jax.tree.leaves(rt.state)]
    for x, y in zip(before, after):
        assert x.tobytes() == y.tobytes()
    ctl.step_all(rounds=1)
    assert rt.step_count == 3
    ctl.partitioner.check_invariants()
    print("GEOMETRY_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "GEOMETRY_OK" in r.stdout
