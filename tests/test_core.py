"""Core multi-block system: topology, partitioner (hypothesis invariants),
lifecycle state machine, interference model, monitor."""
import time

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import interference
from repro.core.block import Block, BlockGrant, BlockRequest, BlockState
from repro.core.monitor import Monitor
from repro.core.partition import AllocationError, Partitioner, mesh_shape_for
from repro.core.registry import Registry
from repro.core.topology import Topology, min_bisection_links, rect_coords


# ---------------------------------------------------------------- topology

def test_topology_links_count():
    t = Topology(n_pods=1, pod_x=4, pod_y=4, wrap=True)
    # 2D torus: 2 links per chip dim -> 2 * n links total
    assert len(t.links()) == 2 * 16


def test_route_is_neighbor_path():
    t = Topology(n_pods=1, pod_x=8, pod_y=8)
    links = t.route((0, 1, 1), (0, 4, 6))
    # torus distance: min over wraparound
    assert len(links) == min(3, 5) + min(5, 3)
    for a, b in links:
        assert b in t.neighbors(a) or a in t.neighbors(b)


def test_rect_bisection():
    t = Topology(n_pods=1, pod_x=8, pod_y=8)
    coords = rect_coords(0, 0, 0, 4, 4)
    # cutting a 4x4 grid in half crosses 4 mesh links
    assert min_bisection_links(coords, t) == 4


# -------------------------------------------------------------- partitioner

@given(sizes=st.lists(st.sampled_from([1, 2, 4, 8, 16]), min_size=1,
                      max_size=6))
@settings(max_examples=40, deadline=None)
def test_partitioner_disjoint_invariant(sizes):
    """Hypothesis: any sequence of allocations yields disjoint contiguous
    rectangles; releasing everything frees every chip."""
    topo = Topology(n_pods=1, pod_x=8, pod_y=8)
    part = Partitioner(topo)
    allocated = []
    for i, n in enumerate(sizes):
        try:
            coords = part.allocate(n, f"b{i}")
        except AllocationError:
            continue
        allocated.append((f"b{i}", coords))
        assert len(coords) == n
        part.check_invariants()
    seen = set()
    for bid, coords in allocated:
        assert not (set(coords) & seen)
        seen |= set(coords)
    for bid, _ in allocated:
        part.release(bid)
    assert len(part.free_chips()) == topo.n_chips


def test_partitioner_contiguity():
    topo = Topology(n_pods=1, pod_x=8, pod_y=8)
    part = Partitioner(topo)
    coords = part.allocate(8, "b0")
    xs = sorted({c[1] for c in coords})
    ys = sorted({c[2] for c in coords})
    assert len(coords) == (xs[-1] - xs[0] + 1) * (ys[-1] - ys[0] + 1)


def test_partitioner_unhealthy_excluded():
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    part = Partitioner(topo)
    part.mark_unhealthy((0, 0, 0))
    coords = part.allocate(16 - 4, "b0")  # 12 chips can't include dead chip
    assert (0, 0, 0) not in coords


def test_partitioner_resize_never_empty():
    topo = Topology(n_pods=1, pod_x=8, pod_y=8)
    part = Partitioner(topo)
    part.allocate(4, "b0")
    new = part.resize("b0", 16)
    assert len(new) == 16
    assert set(part.owned_by("b0")) == set(new)


def test_mesh_shape_for():
    assert mesh_shape_for(256) == (16, 16)
    for n in (1, 2, 4, 8, 16, 64, 512):
        d, m = mesh_shape_for(n)
        assert d * m == n and m <= 16


# ----------------------------------------------------------- state machine

def test_lifecycle_happy_path():
    reg = Registry()
    app = reg.register(BlockRequest("alice", "job", 4))
    grant = BlockGrant.new([(0, 0, 0)], (1, 1), 60.0)
    reg.approve(app, grant)
    reg.confirm(app, grant.token)
    reg.set_state(app, BlockState.ACTIVE)
    reg.set_state(app, BlockState.RUNNING)
    reg.set_state(app, BlockState.DONE)
    reg.set_state(app, BlockState.EXPIRED)
    assert reg.get(app).state == BlockState.EXPIRED


def test_lifecycle_illegal_transition():
    reg = Registry()
    app = reg.register(BlockRequest("alice", "job", 4))
    with pytest.raises(ValueError):
        reg.set_state(app, BlockState.RUNNING)   # must be approved first


def test_confirm_requires_token():
    reg = Registry()
    app = reg.register(BlockRequest("alice", "job", 4))
    grant = BlockGrant.new([(0, 0, 0)], (1, 1), 60.0)
    reg.approve(app, grant)
    with pytest.raises(PermissionError):
        reg.confirm(app, "wrong-token")


def test_expiry_detection():
    reg = Registry()
    app = reg.register(BlockRequest("alice", "job", 4))
    grant = BlockGrant.new([(0, 0, 0)], (1, 1), duration_s=-1.0)  # past
    reg.approve(app, grant)
    assert app in reg.expired()


# ------------------------------------------------------------ interference

def test_contiguous_blocks_fully_isolated():
    """The paper's core claim, structurally: disjoint contiguous blocks share
    zero fabric links."""
    topo = Topology(n_pods=1, pod_x=8, pod_y=8, wrap=False)
    a = rect_coords(0, 0, 0, 4, 4)
    b = rect_coords(0, 4, 4, 4, 4)
    rep = interference.analyze_blocks(topo, {"a": a, "b": b})
    assert rep.isolated
    assert rep.slowdown == {"a": 1.0, "b": 1.0}


def test_fragmented_blocks_interfere():
    """Anti-case: interleaved (non-contiguous) placements route through each
    other and share links — what the allocator's contiguity rule prevents."""
    topo = Topology(n_pods=1, pod_x=8, pod_y=1, wrap=False)
    a = [(0, 0, 0), (0, 2, 0), (0, 4, 0)]   # interleaved with b
    b = [(0, 1, 0), (0, 3, 0), (0, 5, 0)]
    rep = interference.analyze_blocks(topo, {"a": a, "b": b})
    assert not rep.isolated
    assert max(rep.slowdown.values()) > 1.0


def test_fig3_prediction_shape():
    topo = Topology(n_pods=1, pod_x=8, pod_y=8, wrap=False)
    a = rect_coords(0, 0, 0, 4, 4)
    b = rect_coords(0, 4, 0, 4, 4)
    rows = interference.predicted_fig3(topo, a, b,
                                       [2 ** i for i in range(20, 29, 2)])
    assert all(r["shared_links"] == 0 for r in rows)
    # multi-block bandwidth within 10% of single for large messages (Fig. 3)
    big = rows[-1]
    assert big["bw_multi_GBs"] > 0.9 * big["bw_single_GBs"]


# ----------------------------------------------------------------- monitor

def test_monitor_straggler_detection():
    m = Monitor()
    for i in range(16):
        m.record_step("fast", 0.1, 4)
        m.record_step("slow", 0.1 if i < 12 else 0.9, 4)
    assert "slow" in m.stragglers()
    assert "fast" not in m.stragglers()


def test_monitor_usage_accounting():
    m = Monitor()
    m.record_step("b", 2.0, 8)
    assert m.report()["b"]["chip_seconds"] == pytest.approx(16.0)


def test_monitor_roofline_mfu():
    """MFU = useful FLOPs / (EWMA step time x chips x peak); of_roofline
    compares the EWMA to the modeled step-time floor."""
    m = Monitor()
    assert m.mfu("b") is None                   # no roofline, no steps
    m.set_roofline("b", {"model_flops": 8e12, "n_chips": 4,
                         "peak_flops": 1e13, "step_time_s": 0.2,
                         "bottleneck": "compute", "source": "analytic"})
    assert m.mfu("b") is None                   # roofline but no steps yet
    for _ in range(4):
        m.record_step("b", 0.4, 4)              # EWMA converges to 0.4 s
    # 8e12 / (0.4 * 4 * 1e13) = 0.5
    assert m.mfu("b") == pytest.approx(0.5)
    assert m.report()["b"]["mfu"] == pytest.approx(0.5)
    rep = m.roofline_report()
    assert rep["n_modeled"] == 1
    assert rep["mean_mfu"] == pytest.approx(0.5)
    blk = rep["blocks"]["b"]
    assert blk["of_roofline"] == pytest.approx(0.2 / 0.4)
    assert blk["achieved_flops_s"] == pytest.approx(8e12 / 0.4)
    assert blk["bottleneck"] == "compute"
    # a block with a roofline but no steps reports None, not a crash
    m.set_roofline("idle", {"model_flops": 1.0, "n_chips": 1,
                            "peak_flops": 1e13})
    assert m.roofline_report()["blocks"]["idle"]["mfu"] is None
