"""Web gateway end-to-end: the full paper lifecycle over real HTTP against
a live background ClusterDaemon (2 users, oversubscribed pod,
submit -> admit -> preempt -> resume -> download over the wire), token
auth/ownership rejection, and event-feed ordering/long-poll."""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import pytest

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

SIM = {"kind": "sim", "step_s": 0.001, "ckpt_every": 2}


@pytest.fixture
def gw(tmp_path):
    """Background daemon + HTTP gateway on an 8-chip pod, two users with
    distinct profiles plus an admin."""
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root=str(tmp_path / "ckpt"),
                           background=True, tick_interval_s=0.01)
    profiles = ProfileStore([
        UserProfile("alice", "tok-alice", priority=0),
        UserProfile("bob", "tok-bob", priority=5, deadline_s=60.0),
        UserProfile("root", "tok-admin", admin=True),
    ])
    server = GatewayServer(daemon, profiles).start()
    yield server, daemon
    server.stop()
    daemon.stop()


def req(server, method, path, token=None, body=None, timeout=15):
    r = urllib.request.Request(server.url + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def wait_state(server, app_id, token, state, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        _, st = req(server, "GET", f"/v1/blocks/{app_id}", token)
        if st["state"] == state:
            return st
        time.sleep(0.02)
    raise AssertionError(f"{app_id} never reached {state}")


# ------------------------------------------------------------- lifecycle

def test_full_lifecycle_two_users_preempt_resume_download(gw):
    """Oversubscribed pod over the wire: alice fills it, high-priority bob
    evicts her, runs to completion and downloads; alice auto-resumes via
    the daemon's pump thread — every hop a real HTTP request."""
    server, daemon = gw
    s, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "fill", "n_chips": 8, "job": SIM})
    assert s == 201 and a["admitted"] and a["state"] == "running"
    app_a = a["app_id"]
    assert a["grant"]["block_id"].startswith("blk_")
    req(server, "POST", f"/v1/blocks/{app_a}/steps", "tok-alice",
        {"rounds": 4})

    # bob's profile priority (5) outranks alice: submit into the full pod
    # preempts her instead of queueing him
    s, b = req(server, "POST", "/v1/submit", "tok-bob",
               {"job_description": "urgent", "n_chips": 8, "job": SIM})
    assert s == 201 and b["admitted"]
    app_b = b["app_id"]
    _, st_a = req(server, "GET", f"/v1/blocks/{app_a}", "tok-alice")
    assert st_a["state"] == "preempted"
    assert st_a["preempt_count"] == 1

    s, stepped = req(server, "POST", f"/v1/blocks/{app_b}/steps",
                     "tok-bob", {"rounds": 5})
    assert s == 200 and stepped["steps"] == 5
    s, res = req(server, "GET", f"/v1/blocks/{app_b}/download", "tok-bob")
    assert s == 200 and res["steps"] == 5
    s, ex = req(server, "POST", f"/v1/blocks/{app_b}/expire", "tok-bob",
                {})
    assert s == 200 and ex["state"] == "expired"

    # the background pump's tick auto-resumes alice — no client call
    st_a = wait_state(server, app_a, "tok-alice", "running")
    assert st_a["steps"] == 4                      # checkpointed progress
    s, res_a = req(server, "GET", f"/v1/blocks/{app_a}/download",
                   "tok-alice")
    assert s == 200 and res_a["steps"] == 4
    req(server, "POST", f"/v1/blocks/{app_a}/expire", "tok-alice", {})
    daemon.partitioner.check_invariants()


def test_explicit_workflow_review_confirm_activate(gw):
    """The paper's admin-in-the-loop path: register -> admin review ->
    confirm with the block capability token -> activate -> run."""
    server, _ = gw
    s, r = req(server, "POST", "/v1/register", "tok-alice",
               {"job_description": "manual", "n_chips": 4})
    assert s == 201 and r["state"] == "requested"
    app = r["app_id"]
    # non-admin review is refused; admin's succeeds
    s, _ = req(server, "POST", f"/v1/blocks/{app}/review", "tok-alice", {})
    assert s == 403
    s, rv = req(server, "POST", f"/v1/blocks/{app}/review", "tok-admin",
                {})
    assert s == 200 and rv["approved"]
    # wrong capability token is a 409 (PermissionError), right one goes
    s, _ = req(server, "POST", f"/v1/blocks/{app}/confirm", "tok-alice",
               {"token": "nope"})
    assert s == 409
    s, st = req(server, "GET", f"/v1/blocks/{app}", "tok-alice")
    s, cf = req(server, "POST", f"/v1/blocks/{app}/confirm", "tok-alice",
                {"token": st["token"]})
    assert s == 200 and cf["state"] == "confirmed"
    s, _ = req(server, "POST", f"/v1/blocks/{app}/activate", "tok-alice",
               {"job": SIM})
    assert s == 200
    s, rn = req(server, "POST", f"/v1/blocks/{app}/run", "tok-alice", {})
    assert s == 200 and rn["state"] == "running"
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


def test_gang_submit_over_the_wire(gw):
    server, daemon = gw
    s, g = req(server, "POST", "/v1/gangs", "tok-alice", {
        "members": [{"job_description": "t", "n_chips": 4, "job": SIM},
                    {"job_description": "e", "n_chips": 4, "job": SIM}]})
    assert s == 201 and g["admitted"] and len(g["app_ids"]) == 2
    for a in g["app_ids"]:
        blk = daemon.registry.get(a)
        assert blk.state == BlockState.RUNNING
        assert blk.request.gang_id is not None
    for a in g["app_ids"]:
        req(server, "POST", f"/v1/blocks/{a}/expire", "tok-alice", {})


# ------------------------------------------------------------------- auth

def test_auth_rejection(gw):
    server, _ = gw
    s, e = req(server, "GET", "/v1/profile")                # no token
    assert s == 401 and "token" in e["error"]
    s, _ = req(server, "GET", "/v1/profile", "tok-wrong")   # unknown token
    assert s == 401
    # ownership: bob cannot read, step or expire alice's block
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "mine", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    for method, path, body in [
            ("GET", f"/v1/blocks/{app}", None),
            ("POST", f"/v1/blocks/{app}/steps", {"rounds": 1}),
            ("POST", f"/v1/blocks/{app}/expire", {}),
            ("GET", f"/v1/blocks/{app}/download", None)]:
        s, e = req(server, method, path, "tok-bob", body)
        assert s == 403, (path, s, e)
    # admin-only surfaces refuse plain users
    for path in ["/v1/events", f"/v1/blocks/{app}/preempt"]:
        method = "POST" if "preempt" in path else "GET"
        s, _ = req(server, method, path, "tok-alice",
                   {} if method == "POST" else None)
        assert s == 403
    # admin *can* read alice's block and the global feed
    s, _ = req(server, "GET", f"/v1/blocks/{app}", "tok-admin")
    assert s == 200
    s, _ = req(server, "GET", "/v1/events", "tok-admin")
    assert s == 200
    # users only see their own blocks in the listing; admin sees all
    _, mine = req(server, "GET", "/v1/blocks", "tok-bob")
    assert all(b["user"] == "bob" for b in mine["blocks"])
    _, every = req(server, "GET", "/v1/blocks", "tok-admin")
    assert any(b["app_id"] == app for b in every["blocks"])
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


def test_profile_priority_cap_and_field_coercion(gw):
    """A non-admin cannot outrank their own profile priority, and a
    malformed numeric field fails that request with a 400 instead of
    poisoning the shared waitlist."""
    server, daemon = gw
    s, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "sneaky", "n_chips": 4,
                "priority": 100, "job": SIM})
    assert s == 201
    assert daemon.registry.get(a["app_id"]).request.priority == 0
    s, b = req(server, "POST", "/v1/submit", "tok-bob",
               {"job_description": "modest", "n_chips": 4,
                "priority": 3, "job": SIM})   # below bob's profile 5: ok
    assert daemon.registry.get(b["app_id"]).request.priority == 3
    s, e = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "typo", "n_chips": 4,
                "est_steps": "ten"})
    assert s == 400 and "bad submission field" in e["error"]
    for app, tok in [(a["app_id"], "tok-alice"), (b["app_id"], "tok-bob")]:
        req(server, "POST", f"/v1/blocks/{app}/expire", tok, {})


# ------------------------------------------------------------ event feed

def test_event_feed_ordering_and_longpoll(gw):
    server, _ = gw
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "watched", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    req(server, "POST", f"/v1/blocks/{app}/steps", "tok-alice",
        {"rounds": 2})
    _, page = req(server, "GET", f"/v1/blocks/{app}/events", "tok-alice")
    evs = page["events"]
    seqs = [e["seq"] for e in evs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert all(e["app_id"] == app for e in evs)
    # lifecycle transitions arrive in paper order on the block's feed
    states = [e["state"] for e in evs if e["kind"] == "state"]
    assert states == ["approved", "confirmed", "active", "running"]
    assert [e["kind"] for e in evs].count("step") == 2
    assert page["next_after"] == seqs[-1]

    # cursor resume: nothing before/at the cursor is replayed
    _, page2 = req(server, "GET",
                   f"/v1/blocks/{app}/events?after={page['next_after']}",
                   "tok-alice")
    assert page2["events"] == []

    # long-poll: a request parked on the feed returns as soon as another
    # thread causes the next transition
    def expire_later():
        time.sleep(0.2)
        req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})

    t = threading.Thread(target=expire_later)
    t.start()
    t0 = time.monotonic()
    _, page3 = req(server, "GET",
                   f"/v1/blocks/{app}/events"
                   f"?after={page['next_after']}&timeout_s=10",
                   "tok-alice")
    waited = time.monotonic() - t0
    t.join()
    assert page3["events"], "long-poll returned empty despite a transition"
    assert any(e.get("state") == "expired" for e in page3["events"])
    assert waited < 5.0, "long-poll did not wake on the event"


# --------------------------------------------------------------- SSE feed

def sse_frames(server, path, token, max_lines=500, timeout=15):
    """Read one SSE response into (ids, events) lists."""
    r = urllib.request.Request(server.url + path)
    r.add_header("Authorization", f"Bearer {token}")
    ids, events = [], []
    with urllib.request.urlopen(r, timeout=timeout) as resp:
        assert resp.headers["Content-Type"].startswith("text/event-stream")
        cur = {}
        for i, raw in enumerate(resp):
            line = raw.decode().rstrip("\n")
            if i > max_lines:
                break
            if line.startswith("id: "):
                cur["id"] = int(line[4:])
            elif line.startswith("event: "):
                cur["event"] = line[7:]
            elif line.startswith("data: "):
                cur["data"] = json.loads(line[6:])
            elif line == "" and cur:
                if "data" in cur:
                    ids.append(cur["id"])
                    events.append(cur)
                cur = {}
            if events and events[-1]["data"].get("state") == "expired":
                break
    return ids, events


def test_sse_stream_framing_and_cursor_resume(gw):
    """SSE framing: every frame carries id (the bus cursor), event (the
    kind) and JSON data; ids are ordered; a second stream resuming from a
    mid-cursor (as Last-Event-ID would) replays only the tail."""
    server, daemon = gw
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "streamed", "n_chips": 4, "job": SIM,
                "autostep": {"until_steps": 6}})
    app = a["app_id"]

    def expire_when_done():
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            _, st = req(server, "GET", f"/v1/blocks/{app}", "tok-alice")
            if st["state"] == "done":
                req(server, "POST", f"/v1/blocks/{app}/expire",
                    "tok-alice", {})
                return
            time.sleep(0.02)

    t = threading.Thread(target=expire_when_done)
    t.start()
    ids, events = sse_frames(
        server, f"/v1/blocks/{app}/events/stream?after=0&max_s=10",
        "tok-alice")
    t.join()
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    kinds = [e["event"] for e in events]
    assert kinds.count("step") == 6
    states = [e["data"]["state"] for e in events if e["event"] == "state"]
    assert states == ["approved", "confirmed", "active", "running",
                      "done", "expired"]
    for e in events:                      # id mirrors the data's seq
        assert e["id"] == e["data"]["seq"]

    # cursor resume: everything at/before the cursor is not replayed
    mid = ids[len(ids) // 2]
    ids2, events2 = sse_frames(
        server, f"/v1/blocks/{app}/events/stream?after={mid}&max_s=2",
        "tok-alice")
    assert ids2 and min(ids2) > mid
    assert ids2 == [i for i in ids if i > mid][:len(ids2)]

    # ?access_token= authenticates the SSE stream (EventSource cannot
    # set headers) but is NOT accepted on ordinary routes — session
    # tokens must not ride URLs into access logs
    r = urllib.request.Request(
        server.url + f"/v1/blocks/{app}/events/stream"
                     f"?after={mid}&max_s=1&access_token=tok-alice")
    with urllib.request.urlopen(r, timeout=10) as resp:
        assert resp.status == 200
    s, _ = req(server, "GET", f"/v1/blocks/{app}?access_token=tok-alice")
    assert s == 401


def test_sse_disconnect_leaves_gateway_serving(gw):
    """A client dropping its stream mid-flight must not wedge anything:
    the handler thread notices on write and the server keeps serving."""
    server, daemon = gw
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "dropped", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    r = urllib.request.Request(
        server.url + f"/v1/blocks/{app}/events/stream?after=0&max_s=30")
    r.add_header("Authorization", "Bearer tok-alice")
    resp = urllib.request.urlopen(r, timeout=10)
    resp.read(20)                 # stream is live...
    resp.close()                  # ...client vanishes
    for _ in range(3):            # gateway still serves requests promptly
        s, st = req(server, "GET", f"/v1/blocks/{app}", "tok-alice")
        assert s == 200
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


# ------------------------------------------------- autostep over the wire

def test_autostep_routes_owner_gated(gw):
    server, daemon = gw
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "mine", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    # bob cannot arm alice's block; alice can
    s, _ = req(server, "POST", f"/v1/blocks/{app}/autostep", "tok-bob",
               {"until_steps": 5})
    assert s == 403
    s, r = req(server, "POST", f"/v1/blocks/{app}/autostep", "tok-alice",
               {"until_steps": 5, "ckpt_every": 2})
    assert s == 200 and r["autostep"]["enabled"]
    st = wait_state(server, app, "tok-alice", "done")
    assert st["steps"] == 5
    # pace-only body on a non-enabled block 400s cleanly
    s, e = req(server, "POST", f"/v1/blocks/{app}/autostep", "tok-alice",
               {"until_steps": "many"})
    assert s == 400 and "autostep" in e["error"]
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


# ------------------------------------------------------ hardening knobs

def test_rate_limit_429_and_body_cap_413(tmp_path):
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root=str(tmp_path / "ckpt"))
    profiles = ProfileStore([UserProfile("a", "tok-a"),
                             UserProfile("b", "tok-b")])
    server = GatewayServer(daemon, profiles, rate_limit_rps=0.001,
                           rate_limit_burst=3, max_body_bytes=256).start()
    try:
        codes = [req(server, "GET", "/v1/cluster", "tok-a")[0]
                 for _ in range(5)]
        assert codes[:3] == [200, 200, 200] and codes[3:] == [429, 429]
        s, e = req(server, "GET", "/v1/cluster", "tok-a")
        assert s == 429 and e["retry_after_s"] > 0
        # buckets are per session: another token is unaffected
        assert req(server, "GET", "/v1/cluster", "tok-b")[0] == 200
        # ping and the dashboard assets bypass the limiter (no session)
        assert req(server, "GET", "/v1/ping")[0] == 200
        # body cap: an oversized POST is refused with 413 before reading.
        # The server closes the connection without consuming the body, so
        # a client mid-upload may see the reset instead of the response —
        # both are the cap refusing the upload.
        try:
            s, e = req(server, "POST", "/v1/submit", "tok-b",
                       {"n_chips": 4, "pad": "x" * 1000})
            assert s == 413 and "cap" in e["error"]
        except (ConnectionError, urllib.error.URLError):
            pass
        assert req(server, "GET", "/v1/ping")[0] == 200  # still serving
        # under the cap still works (fresh token: limiter untouched)
        s, r = req(server, "POST", "/v1/submit", "tok-b",
                   {"job_description": "ok", "n_chips": 4})
        assert s == 201
    finally:
        server.stop()


# ------------------------------------------- session persistence (registry)

def test_sessions_survive_gateway_restart(tmp_path):
    """Profiles and feed cursors rehydrate from the Registry snapshot: a
    brand-new GatewayServer over the same daemon (empty ProfileStore)
    keeps authenticating the old tokens and resumes feeds from the
    persisted cursor — and the snapshot survives on disk too."""
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    dev = jax.devices()[0]
    state = tmp_path / "state.json"
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root=str(tmp_path / "ckpt"),
                           state_path=str(state))
    profiles = ProfileStore([
        UserProfile("alice", "tok-alice", priority=1, max_chips=8)])
    server = GatewayServer(daemon, profiles).start()
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "persist me", "n_chips": 4,
                "job": SIM})
    app = a["app_id"]
    _, page = req(server, "GET", f"/v1/blocks/{app}/events", "tok-alice")
    cursor = page["next_after"]
    assert cursor > 0
    server.stop()

    # new gateway, EMPTY profile store: everything comes from the registry
    server2 = GatewayServer(daemon, ProfileStore([])).start()
    s, prof = req(server2, "GET", "/v1/profile", "tok-alice")
    assert s == 200 and prof["profile"]["user"] == "alice"
    assert prof["profile"]["priority"] == 1
    s, cur = req(server2, "GET", "/v1/profile/cursors", "tok-alice")
    assert cur["cursors"][app] == cursor
    # after=resume continues from the stored cursor (nothing replayed)
    s, page2 = req(server2, "GET",
                   f"/v1/blocks/{app}/events?after=resume", "tok-alice")
    assert s == 200 and page2["events"] == []
    # the quota came back with the profile
    assert daemon.scheduler.policy.quota_for("alice").max_chips == 8
    server2.stop()

    # and the on-disk snapshot itself carries the session state
    snap = json.loads(state.read_text())
    users = [p["user"] for p in snap["_sessions"]["profiles"]]
    assert "alice" in users


# ------------------------------------------------------------- dashboard

def test_dashboard_static_serving(gw):
    server, _ = gw
    with urllib.request.urlopen(server.url + "/ui", timeout=5) as r:
        html = r.read().decode()
        assert r.headers["Content-Type"].startswith("text/html")
    assert 'id="cluster-report"' in html and "/ui/app.js" in html
    with urllib.request.urlopen(server.url + "/ui/app.js",
                                timeout=5) as r:
        js = r.read().decode()
        assert r.headers["Content-Type"].startswith("text/javascript")
    # the dashboard drives exactly the surfaces this suite already covers
    for path in ("/v1/cluster", "/v1/blocks", "/v1/events/stream",
                 "/v1/blocks/", "/autostep", "/preempt", "/resume"):
        assert path in js, path
    with urllib.request.urlopen(server.url + "/ui/style.css",
                                timeout=5) as r:
        assert r.headers["Content-Type"].startswith("text/css")
    for bad in ("/ui/nope.js", "/ui/..%2Fhandlers.py", "/ui/.hidden"):
        s, _ = req(server, "GET", bad, "tok-alice")
        assert s == 404, bad
