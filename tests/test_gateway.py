"""Web gateway end-to-end: the full paper lifecycle over real HTTP against
a live background ClusterDaemon (2 users, oversubscribed pod,
submit -> admit -> preempt -> resume -> download over the wire), token
auth/ownership rejection, and event-feed ordering/long-poll."""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import pytest

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

SIM = {"kind": "sim", "step_s": 0.001, "ckpt_every": 2}


@pytest.fixture
def gw(tmp_path):
    """Background daemon + HTTP gateway on an 8-chip pod, two users with
    distinct profiles plus an admin."""
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root=str(tmp_path / "ckpt"),
                           background=True, tick_interval_s=0.01)
    profiles = ProfileStore([
        UserProfile("alice", "tok-alice", priority=0),
        UserProfile("bob", "tok-bob", priority=5, deadline_s=60.0),
        UserProfile("root", "tok-admin", admin=True),
    ])
    server = GatewayServer(daemon, profiles).start()
    yield server, daemon
    server.stop()
    daemon.stop()


def req(server, method, path, token=None, body=None, timeout=15):
    r = urllib.request.Request(server.url + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def wait_state(server, app_id, token, state, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        _, st = req(server, "GET", f"/v1/blocks/{app_id}", token)
        if st["state"] == state:
            return st
        time.sleep(0.02)
    raise AssertionError(f"{app_id} never reached {state}")


# ------------------------------------------------------------- lifecycle

def test_full_lifecycle_two_users_preempt_resume_download(gw):
    """Oversubscribed pod over the wire: alice fills it, high-priority bob
    evicts her, runs to completion and downloads; alice auto-resumes via
    the daemon's pump thread — every hop a real HTTP request."""
    server, daemon = gw
    s, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "fill", "n_chips": 8, "job": SIM})
    assert s == 201 and a["admitted"] and a["state"] == "running"
    app_a = a["app_id"]
    assert a["grant"]["block_id"].startswith("blk_")
    req(server, "POST", f"/v1/blocks/{app_a}/steps", "tok-alice",
        {"rounds": 4})

    # bob's profile priority (5) outranks alice: submit into the full pod
    # preempts her instead of queueing him
    s, b = req(server, "POST", "/v1/submit", "tok-bob",
               {"job_description": "urgent", "n_chips": 8, "job": SIM})
    assert s == 201 and b["admitted"]
    app_b = b["app_id"]
    _, st_a = req(server, "GET", f"/v1/blocks/{app_a}", "tok-alice")
    assert st_a["state"] == "preempted"
    assert st_a["preempt_count"] == 1

    s, stepped = req(server, "POST", f"/v1/blocks/{app_b}/steps",
                     "tok-bob", {"rounds": 5})
    assert s == 200 and stepped["steps"] == 5
    s, res = req(server, "GET", f"/v1/blocks/{app_b}/download", "tok-bob")
    assert s == 200 and res["steps"] == 5
    s, ex = req(server, "POST", f"/v1/blocks/{app_b}/expire", "tok-bob",
                {})
    assert s == 200 and ex["state"] == "expired"

    # the background pump's tick auto-resumes alice — no client call
    st_a = wait_state(server, app_a, "tok-alice", "running")
    assert st_a["steps"] == 4                      # checkpointed progress
    s, res_a = req(server, "GET", f"/v1/blocks/{app_a}/download",
                   "tok-alice")
    assert s == 200 and res_a["steps"] == 4
    req(server, "POST", f"/v1/blocks/{app_a}/expire", "tok-alice", {})
    daemon.partitioner.check_invariants()


def test_explicit_workflow_review_confirm_activate(gw):
    """The paper's admin-in-the-loop path: register -> admin review ->
    confirm with the block capability token -> activate -> run."""
    server, _ = gw
    s, r = req(server, "POST", "/v1/register", "tok-alice",
               {"job_description": "manual", "n_chips": 4})
    assert s == 201 and r["state"] == "requested"
    app = r["app_id"]
    # non-admin review is refused; admin's succeeds
    s, _ = req(server, "POST", f"/v1/blocks/{app}/review", "tok-alice", {})
    assert s == 403
    s, rv = req(server, "POST", f"/v1/blocks/{app}/review", "tok-admin",
                {})
    assert s == 200 and rv["approved"]
    # wrong capability token is a 409 (PermissionError), right one goes
    s, _ = req(server, "POST", f"/v1/blocks/{app}/confirm", "tok-alice",
               {"token": "nope"})
    assert s == 409
    s, st = req(server, "GET", f"/v1/blocks/{app}", "tok-alice")
    s, cf = req(server, "POST", f"/v1/blocks/{app}/confirm", "tok-alice",
                {"token": st["token"]})
    assert s == 200 and cf["state"] == "confirmed"
    s, _ = req(server, "POST", f"/v1/blocks/{app}/activate", "tok-alice",
               {"job": SIM})
    assert s == 200
    s, rn = req(server, "POST", f"/v1/blocks/{app}/run", "tok-alice", {})
    assert s == 200 and rn["state"] == "running"
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


def test_gang_submit_over_the_wire(gw):
    server, daemon = gw
    s, g = req(server, "POST", "/v1/gangs", "tok-alice", {
        "members": [{"job_description": "t", "n_chips": 4, "job": SIM},
                    {"job_description": "e", "n_chips": 4, "job": SIM}]})
    assert s == 201 and g["admitted"] and len(g["app_ids"]) == 2
    for a in g["app_ids"]:
        blk = daemon.registry.get(a)
        assert blk.state == BlockState.RUNNING
        assert blk.request.gang_id is not None
    for a in g["app_ids"]:
        req(server, "POST", f"/v1/blocks/{a}/expire", "tok-alice", {})


# ------------------------------------------------------------------- auth

def test_auth_rejection(gw):
    server, _ = gw
    s, e = req(server, "GET", "/v1/profile")                # no token
    assert s == 401 and "token" in e["error"]
    s, _ = req(server, "GET", "/v1/profile", "tok-wrong")   # unknown token
    assert s == 401
    # ownership: bob cannot read, step or expire alice's block
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "mine", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    for method, path, body in [
            ("GET", f"/v1/blocks/{app}", None),
            ("POST", f"/v1/blocks/{app}/steps", {"rounds": 1}),
            ("POST", f"/v1/blocks/{app}/expire", {}),
            ("GET", f"/v1/blocks/{app}/download", None)]:
        s, e = req(server, method, path, "tok-bob", body)
        assert s == 403, (path, s, e)
    # admin-only surfaces refuse plain users
    for path in ["/v1/events", f"/v1/blocks/{app}/preempt"]:
        method = "POST" if "preempt" in path else "GET"
        s, _ = req(server, method, path, "tok-alice",
                   {} if method == "POST" else None)
        assert s == 403
    # admin *can* read alice's block and the global feed
    s, _ = req(server, "GET", f"/v1/blocks/{app}", "tok-admin")
    assert s == 200
    s, _ = req(server, "GET", "/v1/events", "tok-admin")
    assert s == 200
    # users only see their own blocks in the listing; admin sees all
    _, mine = req(server, "GET", "/v1/blocks", "tok-bob")
    assert all(b["user"] == "bob" for b in mine["blocks"])
    _, every = req(server, "GET", "/v1/blocks", "tok-admin")
    assert any(b["app_id"] == app for b in every["blocks"])
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


def test_profile_priority_cap_and_field_coercion(gw):
    """A non-admin cannot outrank their own profile priority, and a
    malformed numeric field fails that request with a 400 instead of
    poisoning the shared waitlist."""
    server, daemon = gw
    s, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "sneaky", "n_chips": 4,
                "priority": 100, "job": SIM})
    assert s == 201
    assert daemon.registry.get(a["app_id"]).request.priority == 0
    s, b = req(server, "POST", "/v1/submit", "tok-bob",
               {"job_description": "modest", "n_chips": 4,
                "priority": 3, "job": SIM})   # below bob's profile 5: ok
    assert daemon.registry.get(b["app_id"]).request.priority == 3
    s, e = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "typo", "n_chips": 4,
                "est_steps": "ten"})
    assert s == 400 and "bad submission field" in e["error"]
    for app, tok in [(a["app_id"], "tok-alice"), (b["app_id"], "tok-bob")]:
        req(server, "POST", f"/v1/blocks/{app}/expire", tok, {})


# ------------------------------------------------------------ event feed

def test_event_feed_ordering_and_longpoll(gw):
    server, _ = gw
    _, a = req(server, "POST", "/v1/submit", "tok-alice",
               {"job_description": "watched", "n_chips": 4, "job": SIM})
    app = a["app_id"]
    req(server, "POST", f"/v1/blocks/{app}/steps", "tok-alice",
        {"rounds": 2})
    _, page = req(server, "GET", f"/v1/blocks/{app}/events", "tok-alice")
    evs = page["events"]
    seqs = [e["seq"] for e in evs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert all(e["app_id"] == app for e in evs)
    # lifecycle transitions arrive in paper order on the block's feed
    states = [e["state"] for e in evs if e["kind"] == "state"]
    assert states == ["approved", "confirmed", "active", "running"]
    assert [e["kind"] for e in evs].count("step") == 2
    assert page["next_after"] == seqs[-1]

    # cursor resume: nothing before/at the cursor is replayed
    _, page2 = req(server, "GET",
                   f"/v1/blocks/{app}/events?after={page['next_after']}",
                   "tok-alice")
    assert page2["events"] == []

    # long-poll: a request parked on the feed returns as soon as another
    # thread causes the next transition
    def expire_later():
        time.sleep(0.2)
        req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})

    t = threading.Thread(target=expire_later)
    t.start()
    t0 = time.monotonic()
    _, page3 = req(server, "GET",
                   f"/v1/blocks/{app}/events"
                   f"?after={page['next_after']}&timeout_s=10",
                   "tok-alice")
    waited = time.monotonic() - t0
    t.join()
    assert page3["events"], "long-poll returned empty despite a transition"
    assert any(e.get("state") == "expired" for e in page3["events"])
    assert waited < 5.0, "long-poll did not wake on the event"
