"""CI gate for the step-time floor: model floors + wall-clock trend.

Usage:
  python benchmarks/check_step_time.py bench-json/BENCH_step_time.json
  python benchmarks/check_step_time.py --update bench-json/BENCH_step_time.json

Two checks against ``benchmarks/baselines/step_time.json`` (committed):

* **Floors (machine-independent)** — the HBM-bytes model's fused-optimizer
  speedup must stay >= 1.5x for the int8-state (production 400B-class)
  path and >= 1.0x for f32 state, and the overlap model must hide > 50% of
  the exposed gradient-allreduce time.  These are properties of the code,
  not the host: a refactor that un-fuses the kernel or un-overlaps the
  allreduce fails CI here.
* **Trend (10% slack)** — for every measured row (us_per_call > 0) present
  in both the baseline and the new run, compute new/old; the gate fails if
  the *median* ratio exceeds 1.10.  Median-of-ratios tolerates one noisy
  row on a shared CI host; a real step-time regression moves them all.

``--update`` rewrites the baseline from the given run (commit the result).
"""
import json
import os
import statistics
import sys

BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baselines", "step_time.json")

FLOORS = [
    # (row name, minimum derived value, what it proves)
    ("opt_hbm_model_i8_speedup_model", 1.5,
     "fused AdamW >= 1.5x over composed reference (int8 state, HBM model)"),
    ("opt_hbm_model_f32_speedup_model", 1.0,
     "fused AdamW never loses HBM traffic vs reference (f32 state)"),
    ("overlap_hidden_frac_model", 0.5,
     "overlapped allreduce hides > 50% of exposed comm (model)"),
]


def rows_by_name(doc):
    return {r["name"]: r for r in doc.get("rows", [])}


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    update = "--update" in argv
    if update:
        argv.remove("--update")
    if len(argv) != 1:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        new = json.load(f)
    if not new.get("ok", False):
        print(f"FAIL: benchmark run itself failed ({argv[0]})")
        return 1
    rows = rows_by_name(new)

    rc = 0
    for name, floor, what in FLOORS:
        row = rows.get(name)
        if row is None:
            print(f"FAIL: missing floor row {name}")
            rc = 1
            continue
        val = float(row["derived"])
        status = "ok" if val >= floor else "FAIL"
        if val < floor:
            rc = 1
        print(f"{status}: {name} = {val:.3f} (floor {floor}) — {what}")

    if update:
        os.makedirs(os.path.dirname(BASELINE), exist_ok=True)
        with open(BASELINE, "w") as f:
            json.dump(new, f, indent=1)
        print(f"baseline updated: {BASELINE}")
        return rc

    if not os.path.exists(BASELINE):
        print(f"no committed baseline at {BASELINE}; floors only")
        return rc
    with open(BASELINE) as f:
        base = rows_by_name(json.load(f))
    ratios = []
    for name, row in rows.items():
        old = base.get(name)
        if old is None:
            continue
        try:
            t_new, t_old = float(row["us_per_call"]), float(old["us_per_call"])
        except ValueError:
            continue
        if t_new > 0 and t_old > 0:
            ratios.append((name, t_new / t_old))
    if not ratios:
        print("no comparable measured rows; trend check skipped")
        return rc
    med = statistics.median(r for _, r in ratios)
    for name, r in sorted(ratios):
        print(f"trend: {name} {r:.3f}x baseline")
    if med > 1.10:
        print(f"FAIL: median step-time ratio {med:.3f}x > 1.10x baseline")
        rc = 1
    else:
        print(f"ok: median step-time ratio {med:.3f}x <= 1.10x baseline")
    return rc


if __name__ == "__main__":
    sys.exit(main())
