"""Fig. 3 reproduction: bisection bandwidth, single block vs. two concurrent
blocks (mpptest analogue).

Run in a subprocess with 8 host devices (benchmarks/run.py does this): block
A = 4 devices, block B = 4 devices, disjoint.  The workload is a bisection
exchange (each half of a block swaps its shard with the other half).  We
measure A alone, then A while B runs the same exchange concurrently from a
second thread — the paper's red vs. green curves.  On this CPU stand-in the
shared resource is host memory bandwidth + the dispatching Python thread,
which plays the role of the paper's shared master node; the structural
ICI-link model (core/interference.py) covers the real-TPU fabric side.
"""
import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_block(devices):
    mesh = Mesh(np.asarray(devices).reshape(len(devices), 1),
                ("data", "model"))
    sh = NamedSharding(mesh, P("data", None))

    @jax.jit
    def exchange(x):
        return jnp.flip(x, axis=0) * 2.0      # halves swap across bisection

    return mesh, sh, exchange


def bench_block(sh, exchange, n_bytes, iters=20):
    cols = max(n_bytes // 4 // 8, 1)
    x = jax.device_put(jnp.ones((8, cols), jnp.float32), sh)
    x = exchange(x)  # warmup/compile
    jax.block_until_ready(x)
    t0 = time.perf_counter()
    for _ in range(iters):
        x = exchange(x)
    jax.block_until_ready(x)
    return (time.perf_counter() - t0) / iters


def main():
    devs = jax.devices()
    assert len(devs) >= 8, "need 8 devices (run via benchmarks/run.py)"
    _, sh_a, ex_a = make_block(devs[:4])
    _, sh_b, ex_b = make_block(devs[4:8])

    sizes = [2 ** i for i in range(12, 25)]     # 4 KB .. 16 MB
    print("name,us_per_call,derived")
    results = []
    for size in sizes:
        t_single = bench_block(sh_a, ex_a, size)

        stop = threading.Event()

        def contend():
            while not stop.is_set():
                bench_block(sh_b, ex_b, size, iters=4)

        th = threading.Thread(target=contend, daemon=True)
        th.start()
        t_multi = bench_block(sh_a, ex_a, size)
        stop.set()
        th.join(timeout=10)

        bw_single = size / t_single / 1e9
        bw_multi = size / t_multi / 1e9
        results.append((size, bw_single, bw_multi))
        print(f"bisect_single_{size},{t_single*1e6:.1f},{bw_single:.3f}")
        print(f"bisect_multi_{size},{t_multi*1e6:.1f},{bw_multi:.3f}")

    # paper's verdict: multi-block affects performance "only slightly"
    big = results[-4:]
    ratio = np.mean([m / s for _, s, m in big])
    print(f"bisect_bw_ratio_large_msgs,0,{ratio:.3f}")


if __name__ == "__main__":
    main()
