"""Federation elasticity: time-to-admit under a pod-capacity ramp and
blast-radius containment on pod death.

Part A (ramp, ISSUE 8 acceptance): one boot pod holds a 9-block backlog of
4-chip requests — one runs, eight wait.  Pods attach at runtime (1 -> 4);
each ``attach_pod`` pumps the waitlist inline, so the time-to-admit for a
backlog block collapses from "wait for a resident's usage period to end"
to "one attach round-trip".  Measures the per-attach admit latency and how
much of the backlog the ramp absorbed.

Part B (blast radius): four pods, one RUNNING 4-chip tenant each, steps in
flight.  One pod dies.  The victim preempts (checkpoint -> release ->
requeue); the other three tenants must keep their exact placement, the
dead pod must hold zero owned chips, and attaching spare capacity must
auto-resume the victim elsewhere.  Blast radius = victims / tenants.

Uses SimRuntime so the numbers isolate control-plane behaviour from XLA
noise.  Output follows the repo's benchmark CSV convention:
name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/federation_elasticity.py
"""
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology

CHIPS = 4               # every block fills one 2x2 pod
BACKLOG = 9             # ramp backlog (1 admitted + 8 queued at start)
RAMP = 3                # pods attached at runtime: 1 -> 4
STEP_S = 0.001


def build() -> ClusterDaemon:
    topo = Topology(n_pods=1, pod_x=2, pod_y=2)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root="artifacts/federation_bench_ckpt")


def start_block(d: ClusterDaemon, app: str) -> None:
    blk = d.registry.get(app)
    d.confirm(app, blk.grant.token)
    d.registry.set_state(app, BlockState.ACTIVE)
    d.registry.set_state(app, BlockState.RUNNING)
    d.runtimes[app] = SimRuntime(STEP_S)


def bench_ramp():
    """Returns (per-attach admit latencies us, submit-to-admit waits s,
    blocks admitted by the ramp)."""
    d = build()
    submitted, apps = {}, []
    for i in range(BACKLOG):
        app, grant = d.submit(f"user{i}", "ramp backlog", CHIPS,
                              duration_s=60.0)
        submitted[app] = time.perf_counter()
        apps.append(app)
        if grant is not None:
            start_block(d, app)
    admitted = {a for a in apps
                if d.registry.get(a).state != BlockState.QUEUED}
    base = len(admitted)
    attach_us, waits = [], []
    for r in range(RAMP):
        t0 = time.perf_counter()
        d.attach_pod(2, 2, name=f"ramp{r}")
        attach_us.append((time.perf_counter() - t0) * 1e6)
        for a in apps:
            if a in admitted:
                continue
            blk = d.registry.get(a)
            if blk.state != BlockState.QUEUED:
                admitted.add(a)
                waits.append(time.perf_counter() - submitted[a])
                if blk.state == BlockState.APPROVED:
                    start_block(d, a)
    return attach_us, waits, len(admitted) - base


def bench_blast():
    """Returns (victims, tenants, leaked chips, untouched co-tenants,
    fail latency us, victim resumed after spare attach)."""
    d = build()
    for r in range(3):
        d.attach_pod(2, 2, name=f"pod{r + 1}")
    apps = []
    for i in range(4):
        app, grant = d.submit(f"tenant{i}", "resident", CHIPS,
                              duration_s=60.0)
        assert grant is not None, "tenant did not fit its own pod"
        start_block(d, app)
        apps.append(app)
    d.run_steps({a: 2 for a in apps})          # steps in flight everywhere
    victim_pod = d.registry.get(apps[-1]).grant.coords[0][0]
    before = {a: list(d.registry.get(a).grant.coords) for a in apps}
    t0 = time.perf_counter()
    victims = d.fail_pod(victim_pod, reason="bench: power loss")
    fail_us = (time.perf_counter() - t0) * 1e6
    dead = d.pods.pod(victim_pod)
    leaked = sum(1 for info in dead.part.chips.values()
                 if info.owner is not None)
    untouched = sum(
        1 for a in apps if a not in victims
        and d.registry.get(a).state == BlockState.RUNNING
        and list(d.registry.get(a).grant.coords) == before[a])
    d.attach_pod(2, 2, name="spare")           # capacity returns...
    resumed = all(d.registry.get(a).state == BlockState.RUNNING
                  for a in victims)            # ...victim resumes on it
    return victims, apps, leaked, untouched, fail_us, resumed


def main():
    attach_us, waits, ramp_admitted = bench_ramp()
    victims, apps, leaked, untouched, fail_us, resumed = bench_blast()

    p50_attach = statistics.median(attach_us)
    p50_wait = statistics.median(waits) if waits else 0.0
    radius = 100.0 * len(victims) / len(apps)

    print("name,us_per_call,derived")
    print(f"ramp_attach_to_admit_p50,{p50_attach:.0f},{ramp_admitted}")
    print(f"ramp_backlog_wait_p50,{p50_wait * 1e6:.0f},{p50_wait:.4f}")
    print(f"ramp_pods_attached,0,{RAMP}")
    print(f"blast_fail_pod,{fail_us:.0f},{len(victims)}")
    print(f"blast_radius_pct,0,{radius:.0f}")
    print(f"blast_leaked_chips,0,{leaked}")
    print(f"blast_untouched_cotenants,0,{untouched}")
    print(f"blast_victim_resumed,0,{int(resumed)}")

    ok = True
    if ramp_admitted < RAMP:
        print("WARNING: pod ramp admitted less than one block per attach",
              file=sys.stderr)
        ok = False
    if leaked:
        print("WARNING: dead pod still owns chips", file=sys.stderr)
        ok = False
    if untouched != len(apps) - len(victims):
        print("WARNING: pod death disturbed a co-tenant placement",
              file=sys.stderr)
        ok = False
    if not resumed:
        print("WARNING: victim did not auto-resume on spare capacity",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
