"""Web gateway service benchmark: request throughput and admit-to-event
feed latency.

Two numbers matter for "control and monitor the whole system over web":

* **requests/s** — concurrent read traffic (block status + cluster
  report) against the threaded HTTP server while the daemon pump is live.
* **admit-to-event latency** — the freshness of the monitoring feed: time
  from a client's submit request to the moment the resulting ``admitted``
  event is *observed on a long-poll feed* by an independent watcher.

Sim jobs keep XLA out of the loop — this measures the gateway + daemon
command path, not model compiles.  Output follows the repo's benchmark
CSV convention: name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/gateway_throughput.py
"""
import json
import os
import statistics
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.daemon import ClusterDaemon
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

N_STATUS = 400          # read requests across READERS threads
READERS = 4
N_ADMITS = 30           # submit -> admitted-event-observed cycles


def req(base, method, path, token, body=None, timeout=30):
    r = urllib.request.Request(base + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    r.add_header("Authorization", f"Bearer {token}")
    with urllib.request.urlopen(r, timeout=timeout) as resp:
        return json.loads(resp.read())


def main() -> int:
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root="artifacts/gw_bench_ckpt",
                           background=True, tick_interval_s=0.01)
    profiles = ProfileStore([
        UserProfile("u", "tok-u"),
        UserProfile("root", "tok-admin", admin=True)])
    server = GatewayServer(daemon, profiles).start()
    base = server.url
    sim = {"kind": "sim", "step_s": 0.001}

    # ------------------------------------------------- read throughput
    seed = req(base, "POST", "/v1/submit", "tok-u",
               {"job_description": "probe", "n_chips": 4, "job": sim})
    app = seed["app_id"]

    def reader(n, errs):
        for i in range(n):
            try:
                path = (f"/v1/blocks/{app}" if i % 2 else "/v1/cluster")
                req(base, "GET", path, "tok-u")
            except Exception:
                errs.append(1)

    errs = []
    threads = [threading.Thread(target=reader,
                                args=(N_STATUS // READERS, errs))
               for _ in range(READERS)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    rps = (N_STATUS - len(errs)) / wall
    us_per_req = wall / max(1, N_STATUS - len(errs)) * 1e6

    # -------------------------------------- admit-to-event feed latency
    submit_t = {}
    observe_t = {}
    stop = threading.Event()

    # cursor snapshotted *before* the watcher starts and before any
    # submit: a slow thread start must not skip early admitted events
    start_cursor = daemon.bus.latest_seq

    def watcher():
        """Independent monitor long-polling the global feed, timestamping
        each admitted event the moment it becomes visible."""
        after = start_cursor
        while not stop.is_set():
            page = req(base, "GET",
                       f"/v1/events?after={after}&timeout_s=2"
                       f"&kinds=admitted", "tok-admin")
            for ev in page["events"]:
                observe_t.setdefault(ev["app_id"], time.perf_counter())
            after = page["next_after"]

    w = threading.Thread(target=watcher)
    w.start()
    for i in range(N_ADMITS):
        t0 = time.perf_counter()
        r = req(base, "POST", "/v1/submit", "tok-u",
                {"job_description": f"lat {i}", "n_chips": 4, "job": sim})
        submit_t[r["app_id"]] = t0
        # bounded pod: retire each block so the next admits immediately
        req(base, "POST", f"/v1/blocks/{r['app_id']}/expire", "tok-u", {})
    deadline = time.monotonic() + 10.0
    while len(observe_t) < len(submit_t) and time.monotonic() < deadline:
        time.sleep(0.01)
    stop.set()
    w.join()

    lats = [observe_t[a] - t for a, t in submit_t.items()
            if a in observe_t]
    p50_ms = statistics.median(lats) * 1e3 if lats else float("inf")
    max_ms = max(lats) * 1e3 if lats else float("inf")

    server.stop()
    daemon.stop()

    print("name,us_per_call,derived")
    print(f"gateway_read_requests_per_s,{us_per_req:.0f},{rps:.0f}")
    print(f"gateway_admit_event_latency_p50_ms,0,{p50_ms:.2f}")
    print(f"gateway_admit_event_latency_max_ms,0,{max_ms:.2f}")
    print(f"gateway_admit_events_observed,0,{len(lats)}/{N_ADMITS}")

    ok = True
    if errs or len(lats) < N_ADMITS:
        print(f"WARNING: {len(errs)} read errors, "
              f"{N_ADMITS - len(lats)} unobserved admits", file=sys.stderr)
        ok = False
    if rps < 50:
        print("WARNING: gateway read throughput below 50 req/s",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
