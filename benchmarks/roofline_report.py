"""Roofline table from the dry-run artifacts (no devices needed).

Reads artifacts/dryrun/sweep.jsonl (written by repro.launch.dryrun --all)
and emits one CSV row per executed cell: the modeled step time (max of the
three terms, us) and the roofline fraction as `derived`.
"""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(pattern="sweep.jsonl"):
    cells = {}
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(path) as f:
            for line in f:
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = (d.get("arch"), d.get("shape"), d.get("mesh"))
                cells[key] = d          # later runs override earlier
    return cells


def main():
    cells = load_cells()
    print("name,us_per_call,derived")
    if not cells:
        print("roofline_no_artifacts,0,0")
        return
    for (arch, shape, mesh), d in sorted(cells.items()):
        status = d.get("status", "?")
        tag = f"roofline_{arch}_{shape}_{mesh}"
        if status != "ok":
            print(f"{tag},0,skip")
            continue
        r = d["roofline"]
        print(f"{tag},{r['step_time_s']*1e6:.0f},{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
