"""§4 claim on real tenant jobs: training step time of a block running alone
vs. the same block while a second block trains concurrently (shared host =
the paper's shared master node).  Run via benchmarks/run.py (8 devices).
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import numpy as np

import repro.configs as C
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig


def timed_steps(ctl, rounds=6):
    out = ctl.step_all(rounds=rounds)
    return {a: float(np.median([r["step_s"] for r in rs[1:]]))
            for a, rs in out.items()}


def main():
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/bench_ckpt")
    shape = ShapeConfig("b", "train", seq_len=128, global_batch=8,
                        microbatch=1)
    opt = OptConfig(warmup_steps=2, total_steps=100)

    a1 = ctl.register("alice", "dense job", 4, arch="deepseek_7b")
    g1 = ctl.review(a1)
    ctl.confirm(a1, g1.token)
    ctl.activate(a1, JobSpec(C.get_smoke("deepseek_7b"), shape, opt=opt))
    ctl.run(a1)

    print("name,us_per_call,derived")
    t_alone = timed_steps(ctl)[a1]
    print(f"train_step_single_block,{t_alone*1e6:.0f},1.0")

    a2 = ctl.register("bob", "xlstm job", 4, arch="xlstm_350m")
    g2 = ctl.review(a2)
    ctl.confirm(a2, g2.token)
    ctl.activate(a2, JobSpec(C.get_smoke("xlstm_350m"), shape, opt=opt))
    ctl.run(a2)
    rep = ctl.interference_report()

    both = timed_steps(ctl)
    t_multi = both[a1]
    print(f"train_step_multi_block,{t_multi*1e6:.0f},{t_multi/t_alone:.3f}")
    print(f"shared_links,0,{sum(rep.shared_links.values())}")
    print(f"isolated,0,{int(rep.isolated)}")


if __name__ == "__main__":
    main()
