"""Autostep engine benchmark: aggregate steps/s vs client-driven
dispatch, and SSE event fan-out latency.

Two questions decide whether daemon-side execution is a free win:

* **throughput parity** — the same 4-block workload run (a) client-driven
  (``run_steps`` loops, the pre-engine way) and (b) engine-driven (blocks
  armed with ``until_steps``, the pump does everything).  The acceptance
  bar is autostep within 10% of the client-driven aggregate steps/s: the
  simulator's serial step chains bound both runs, so any bigger gap is
  engine overhead (dispatch windows starving, pump latency).
* **SSE fan-out latency** — with N concurrent Server-Sent-Events watchers
  holding the cluster stream over real HTTP, how stale is the feed?
  Measured publish -> observed-on-the-wire per watcher per marker event.

Sim jobs keep XLA out of the loop.  Output follows the repo's benchmark
CSV convention: name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/engine_throughput.py
"""
import json
import os
import statistics
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import SimJobSpec
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

N_BLOCKS = 4
STEPS = 150
STEP_S = 0.003
WATCHERS = 8
MARKERS = 20


def build(background: bool) -> ClusterDaemon:
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root="artifacts/engine_bench_ckpt",
                         background=background, tick_interval_s=0.01)


def submit_blocks(daemon):
    apps = []
    for i in range(N_BLOCKS):
        app, grant = daemon.submit(f"u{i}", f"bench {i}", 4,
                                   job=SimJobSpec(step_s=STEP_S))
        assert grant is not None
        apps.append(app)
    return apps


def client_driven() -> float:
    """The pre-engine way: a client loop POSTing steps (here: direct
    ``run_steps`` calls — no HTTP, so this is the *generous* baseline)."""
    daemon = build(background=False)
    apps = submit_blocks(daemon)
    t0 = time.perf_counter()
    daemon.run_steps({a: STEPS for a in apps})
    wall = time.perf_counter() - t0
    for a in apps:
        assert daemon.runtime(a).step_count == STEPS
        daemon.expire(a)
    return N_BLOCKS * STEPS / wall


def engine_driven() -> float:
    """Blocks armed at submission; the pump thread does all stepping."""
    daemon = build(background=True)
    apps = submit_blocks(daemon)
    t0 = time.perf_counter()
    for a in apps:
        daemon.autostep_enable(a, until_steps=STEPS)
    while not all(daemon.registry.get(a).state == BlockState.DONE
                  for a in apps):
        time.sleep(0.002)
        assert time.perf_counter() - t0 < 60, "engine run stalled"
    wall = time.perf_counter() - t0
    for a in apps:
        assert daemon.runtime(a).step_count == STEPS
    daemon.stop()
    return N_BLOCKS * STEPS / wall


def sse_fanout():
    """p50/max publish->observe latency across WATCHERS concurrent SSE
    clients on the cluster-wide stream."""
    daemon = build(background=True)
    profiles = ProfileStore([UserProfile("root", "tok-admin", admin=True)])
    server = GatewayServer(daemon, profiles).start()
    observed = {}        # (marker, watcher) -> t_observed
    ready = threading.Barrier(WATCHERS + 1)

    def watch(idx):
        url = (f"{server.url}/v1/events/stream?after=0&kinds=bench"
               f"&max_s=30&access_token=tok-admin")
        resp = urllib.request.urlopen(url, timeout=40)
        ready.wait()
        got = 0
        for raw in resp:
            line = raw.decode().rstrip("\n")
            if not line.startswith("data: "):
                continue
            ev = json.loads(line[len("data: "):])
            observed[(ev["marker"], idx)] = time.perf_counter()
            got += 1
            if got >= MARKERS:
                resp.close()
                return

    threads = [threading.Thread(target=watch, args=(i,), daemon=True)
               for i in range(WATCHERS)]
    for t in threads:
        t.start()
    ready.wait()
    time.sleep(0.2)                       # let every watcher park in wait()
    sent = {}
    for m in range(MARKERS):
        sent[m] = time.perf_counter()
        daemon.bus.publish("bench", app_id="bench", marker=m)
        time.sleep(0.02)
    deadline = time.monotonic() + 10.0
    want = MARKERS * WATCHERS
    while len(observed) < want and time.monotonic() < deadline:
        time.sleep(0.01)
    for t in threads:
        t.join(2.0)
    lats = [t_obs - sent[m] for (m, _i), t_obs in observed.items()]
    server.stop()
    daemon.stop()
    p50 = statistics.median(lats) * 1e3 if lats else float("inf")
    mx = max(lats) * 1e3 if lats else float("inf")
    return p50, mx, len(observed), want


def main() -> int:
    client_sps = client_driven()
    engine_sps = engine_driven()
    ratio = engine_sps / client_sps
    p50_ms, max_ms, seen, want = sse_fanout()

    print("name,us_per_call,derived")
    print(f"client_driven_steps_per_s,{1e6 / client_sps:.0f},"
          f"{client_sps:.0f}")
    print(f"autostep_steps_per_s,{1e6 / engine_sps:.0f},{engine_sps:.0f}")
    print(f"autostep_vs_client_ratio,0,{ratio:.3f}")
    print(f"sse_fanout_latency_p50_ms,0,{p50_ms:.2f}")
    print(f"sse_fanout_latency_max_ms,0,{max_ms:.2f}")
    print(f"sse_fanout_observed,0,{seen}/{want}")

    ok = True
    if ratio < 0.9:
        print(f"WARNING: autostep steps/s {engine_sps:.0f} more than 10% "
              f"below client-driven {client_sps:.0f}", file=sys.stderr)
        ok = False
    if seen < want:
        print(f"WARNING: {want - seen} SSE deliveries unobserved",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
