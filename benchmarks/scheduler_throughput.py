"""Event-driven dispatch vs. the old fixed-order round-robin ``step_all``.

Scenario (ISSUE acceptance): 3 fast blocks + 1 block 4x slower, each owed
the same amount of device compute (fast blocks owe 4x the steps).  The old
dispatcher rounds over *every* active block and blocks in fixed order, so
each round is gated by the slowest still-active block; the event-driven
loop keeps per-block in-flight windows and harvests completions in finish
order, so the makespan collapses to the longest single chain.

Uses SimRuntime (wall-clock model of a block's serial step chain, blocks
concurrent across sub-meshes) so the comparison isolates *dispatcher*
semantics from XLA/CPU-contention noise.  Output follows the repo's
benchmark CSV convention: name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/scheduler_throughput.py
"""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scheduler import SimRuntime, drive

FAST_S = 0.010          # fast block step time
SLOW_S = 0.040          # slow block: 4x slower
FAST_STEPS = 16         # equal compute: 16 * 10ms == 4 * 40ms
SLOW_STEPS = 4


def make_blocks():
    return {"fast0": SimRuntime(FAST_S), "fast1": SimRuntime(FAST_S),
            "fast2": SimRuntime(FAST_S), "slow": SimRuntime(SLOW_S)}


TARGETS = {"fast0": FAST_STEPS, "fast1": FAST_STEPS,
           "fast2": FAST_STEPS, "slow": SLOW_STEPS}


def old_round_robin(rts, targets):
    """Seed ``step_all`` semantics: per round, async-dispatch one step to
    every still-active block, then block_until_ready in fixed order."""
    remaining = dict(targets)
    while any(remaining.values()):
        active = [a for a, n in remaining.items() if n > 0]
        for a in active:
            rts[a].dispatch()
            remaining[a] -= 1
        for a in active:          # fixed-order wait: head-of-line blocking
            rts[a].poll(block=True)


def old_naive(rts, targets):
    """Old API as actually usable: ``step_all(rounds=N)`` has no per-block
    targets, so every block steps max(targets) times."""
    rounds = max(targets.values())
    uniform = {a: rounds for a in targets}
    old_round_robin(rts, uniform)


def timed(fn, *args):
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def main():
    total_steps = sum(TARGETS.values())
    t_naive = timed(old_naive, make_blocks(), TARGETS)
    t_rr = timed(old_round_robin, make_blocks(), TARGETS)
    t_event = timed(lambda: drive(make_blocks(), TARGETS, max_inflight=2))

    print("name,us_per_call,derived")
    print(f"step_all_naive_uniform_rounds,{t_naive/total_steps*1e6:.0f},"
          f"{t_naive:.3f}")
    print(f"step_all_round_robin,{t_rr/total_steps*1e6:.0f},{t_rr:.3f}")
    print(f"event_driven_dispatch,{t_event/total_steps*1e6:.0f},"
          f"{t_event:.3f}")
    print(f"speedup_vs_round_robin,0,{t_rr/t_event:.2f}")
    print(f"speedup_vs_naive,0,{t_naive/t_event:.2f}")
    # ideal: event ~= longest chain (160ms); rr ~= 4*40 + 12*10 = 280ms
    if t_event >= t_rr:
        print("WARNING: event-driven dispatch did not beat round-robin",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
