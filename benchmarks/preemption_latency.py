"""High-priority admission latency: preemptive vs. wait-for-expiry.

Scenario (ISSUE 2 acceptance): a 16-chip pod is saturated by four
low-priority blocks (periodic checkpoints every CKPT_EVERY steps).  A burst
of high-priority requests then arrives.  Without preemption each one waits
for a low-priority block's usage period to end; with checkpoint-backed
preemption the scheduler suspends a victim (drain -> sync save -> release)
and admits the waiter immediately, and the victim auto-resumes from its
checkpoint once capacity frees.

Measures the high-priority P50 admission wait in both modes and the
victims' progress-lost steps (bounded by the checkpoint interval, since
victim selection minimizes steps-since-last-checkpoint and suspend itself
checkpoints).  Uses SimRuntime so the comparison isolates *scheduler*
semantics from XLA noise.  Output follows the repo's benchmark CSV
convention: name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/preemption_latency.py
"""
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology

N_LOW = 4               # low-priority blocks saturating the pod
N_HIGH = 3              # high-priority burst
CHIPS_EACH = 4
LOW_PERIOD_S = 0.35     # low blocks' usage period (what non-preemptive waits)
STEP_S = 0.002
CKPT_EVERY = 5          # periodic checkpoint interval (steps)
HIGH_STEPS = 20         # steps a high-priority block runs before expiring


def build(preemption: bool):
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    dev = jax.devices()[0]
    ctl = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                            ckpt_root="artifacts/preempt_bench_ckpt")
    ctl.scheduler.preemption_enabled = preemption
    low = []
    for i in range(N_LOW):
        app, grant = ctl.submit(f"low{i}", "background", CHIPS_EACH,
                                priority=0, duration_s=LOW_PERIOD_S)
        assert grant is not None
        ctl.confirm(app, grant.token)
        ctl.registry.set_state(app, BlockState.ACTIVE)
        ctl.registry.set_state(app, BlockState.RUNNING)
        ctl.runtimes[app] = SimRuntime(STEP_S, ckpt_every=CKPT_EVERY)
        low.append(app)
    return ctl, low


def run_mode(preemption: bool):
    """Returns (high-priority waits, progress-lost steps, makespan)."""
    ctl, low = build(preemption)
    t0 = time.perf_counter()
    ctl.step_all(rounds=7)                   # low blocks accrue progress

    highs = {}
    for i in range(N_HIGH):
        app, grant = ctl.submit(f"high{i}", "urgent", CHIPS_EACH,
                                priority=5, duration_s=10.0)
        highs[app] = {"submitted": time.perf_counter(),
                      "admitted": (time.perf_counter()
                                   if grant is not None else None)}
        if grant is not None:
            ctl.confirm(app, grant.token)
            ctl.registry.set_state(app, BlockState.ACTIVE)
            ctl.registry.set_state(app, BlockState.RUNNING)
            ctl.runtimes[app] = SimRuntime(STEP_S)

    while True:
        # drive whatever runs, retire finished high blocks, tick the clock
        running = ctl.registry.by_state(BlockState.RUNNING)
        if running:
            ctl.run_steps({a: 2 for a in running})
        for app in list(highs):
            info = highs[app]
            blk = ctl.registry.get(app)
            if info["admitted"] is None and blk.grant is not None and \
                    blk.state not in (BlockState.QUEUED, BlockState.DENIED):
                info["admitted"] = time.perf_counter()
                ctl.confirm(app, blk.grant.token)
                ctl.registry.set_state(app, BlockState.ACTIVE)
                ctl.registry.set_state(app, BlockState.RUNNING)
                ctl.runtimes[app] = SimRuntime(STEP_S)
            rt = ctl.runtimes.get(app)
            if rt is not None and rt.step_count >= HIGH_STEPS and \
                    blk.state == BlockState.RUNNING:
                ctl.registry.set_state(app, BlockState.DONE)
                ctl.expire(app)
        ctl.tick()
        done = all(ctl.registry.get(a).state == BlockState.EXPIRED
                   for a in highs)
        if done:
            break
        time.sleep(0.005)

    waits = [h["admitted"] - h["submitted"] for h in highs.values()]
    lost = list(ctl.monitor.progress_lost_steps)
    return waits, lost, time.perf_counter() - t0


def main():
    waits_np, _, span_np = run_mode(preemption=False)
    waits_p, lost, span_p = run_mode(preemption=True)
    p50_np = statistics.median(waits_np)
    p50_p = statistics.median(waits_p)

    print("name,us_per_call,derived")
    print(f"high_pri_p50_wait_no_preemption,{p50_np * 1e6:.0f},{p50_np:.4f}")
    print(f"high_pri_p50_wait_preemption,{p50_p * 1e6:.0f},{p50_p:.4f}")
    print(f"high_pri_max_wait_no_preemption,"
          f"{max(waits_np) * 1e6:.0f},{max(waits_np):.4f}")
    print(f"high_pri_max_wait_preemption,"
          f"{max(waits_p) * 1e6:.0f},{max(waits_p):.4f}")
    print(f"wait_speedup_p50,0,{p50_np / max(p50_p, 1e-9):.1f}")
    print(f"victim_preemptions,0,{len(lost)}")
    print(f"victim_max_progress_lost_steps,0,{max(lost) if lost else 0}")
    print(f"ckpt_interval_steps,0,{CKPT_EVERY}")
    print(f"makespan_no_preemption_s,0,{span_np:.3f}")
    print(f"makespan_preemption_s,0,{span_p:.3f}")

    ok = True
    if p50_p >= p50_np:
        print("WARNING: preemption did not lower high-priority P50 wait",
              file=sys.stderr)
        ok = False
    if lost and max(lost) > CKPT_EVERY:
        print("WARNING: victim progress loss exceeded checkpoint interval",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
