"""Serve data-plane benchmark: continuous batching vs sequential decode.

The tentpole claim of the paged-KV serve engine: N users' generate
sessions multiplexed onto one slot-batched decode loop beat the
pre-engine serving model (one dense prefill+decode context at a time) on
both axes the paper's shared-cluster story cares about:

* **aggregate tokens/s** — one batched ``decode_step_paged`` call per
  round amortizes dispatch + weights over ``max_slots`` sessions, where
  sequential decode pays a full device round-trip per token per session;
* **p99 time-to-first-token** — continuous batching admits a session the
  moment a slot frees (prefill + first token immediately), while the
  sequential baseline queues whole sessions behind each other, so late
  sessions' TTFT stretches to the entire backlog.

Both planes run the same tiny smoke model on one host device with all
sessions submitted at t=0 (the "100/1000 concurrent users hit the serve
block at once" worst case).  The acceptance gate — continuous batching
>= 5x sequential tokens/s at 100 concurrent sessions — fails the
benchmark process (CI marks BENCH_serve.json ok=false).

Output follows the repo CSV convention: name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/serve_throughput.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import AttentionConfig, ModelConfig
from repro.serve.decode_scheduler import DecodeScheduler

PROMPT_LEN = 8
MAX_NEW = 16
MAX_SEQ = 32
PAGE = 4
SLOTS = 32
SPEEDUP_GATE = 5.0


def smoke_cfg() -> ModelConfig:
    return ModelConfig(name="serve_bench", family="dense", n_layers=2,
                       d_model=64, vocab_size=256, d_ff=128,
                       attention=AttentionConfig(n_heads=4, n_kv_heads=2,
                                                 head_dim=16),
                       param_dtype="float32")


def prompts(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 256, size=PROMPT_LEN).tolist()
            for _ in range(n)]


def p99(xs):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(0.99 * (len(xs) - 1) + 0.999))]


# --------------------------------------------------------- continuous plane
def run_continuous(cfg, params, n: int):
    sch = DecodeScheduler(cfg, params, page_size=PAGE, n_pages=0,
                          max_slots=SLOTS, max_seq_len=MAX_SEQ)
    # warm this scheduler's own executables (admit bucket + decode) —
    # jit caches are per-instance, so a throwaway scheduler wouldn't help
    for p in prompts(2, seed=99):
        sch.submit(p, max_new_tokens=2)
    while sch.has_work:
        sch.step()
    sch.ttft_s.clear()
    base_tokens = sch.tokens_generated
    for p in prompts(n):
        sch.submit(p, max_new_tokens=MAX_NEW)
    t0 = time.perf_counter()
    rounds = 0
    while sch.has_work:
        sch.step()
        rounds += 1
    wall = time.perf_counter() - t0
    assert sch.finished == n + 2, (sch.finished, n)
    # TTFT clocks start at submit(); re-base on the drain start so queue
    # time (not setup time) is what the percentile reflects
    base = min(sch.ttft_s)
    return {"tokens": sch.tokens_generated - base_tokens, "wall_s": wall,
            "rounds": rounds, "ttft": [t - base for t in sch.ttft_s]}


# --------------------------------------------------------- sequential plane
def run_sequential(cfg, params, n: int):
    """The pre-engine baseline: one dense serve context, whole sessions
    one after another (prefill, then MAX_NEW single-token decode steps)."""
    prefill = jax.jit(lambda p, t, c: model_lib.prefill(
        cfg=cfg, params=p, batch={"tokens": t}, cache=c))
    decode = jax.jit(
        lambda p, t, c, l: model_lib.decode_step(p, cfg, t, c, l),
        donate_argnums=(2,), static_argnums=())
    toks = prompts(n)
    # warm both executables outside the timed region (the continuous plane
    # compiles during its warmup admission too)
    cache = model_lib.init_cache(cfg, 1, MAX_SEQ)
    logits, cache = prefill(params, jnp.asarray([toks[0]], jnp.int32), cache)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    _, cache = decode(params, nxt, cache, jnp.int32(PROMPT_LEN))
    jax.block_until_ready(cache)

    ttft = []
    tokens = 0
    t0 = time.perf_counter()
    for p in toks:
        cache = model_lib.init_cache(cfg, 1, MAX_SEQ)
        logits, cache = prefill(params, jnp.asarray([p], jnp.int32), cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        ttft.append(time.perf_counter() - t0)   # all submitted at t0
        tokens += 1
        for i in range(MAX_NEW - 1):
            logits, cache = decode(params, nxt, cache,
                                   jnp.int32(PROMPT_LEN + i))
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            tokens += 1
        jax.block_until_ready(nxt)
    wall = time.perf_counter() - t0
    return {"tokens": tokens, "wall_s": wall, "ttft": ttft}


def main() -> None:
    cfg = smoke_cfg()
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

    rows = []
    speedup_100 = None
    for n in (100, 1000):
        cont = run_continuous(cfg, params, n)
        seq = run_sequential(cfg, params, n)
        c_tps = cont["tokens"] / cont["wall_s"]
        s_tps = seq["tokens"] / seq["wall_s"]
        speedup = c_tps / s_tps
        if n == 100:
            speedup_100 = speedup
        rows += [
            (f"serve_cont_tput_{n}",
             f"{1e6 * cont['wall_s'] / cont['tokens']:.1f}",
             f"{c_tps:.0f}_tok_per_s"),
            (f"serve_cont_ttft_p99_{n}", "0",
             f"{1e3 * p99(cont['ttft']):.1f}_ms"),
            (f"serve_seq_tput_{n}",
             f"{1e6 * seq['wall_s'] / seq['tokens']:.1f}",
             f"{s_tps:.0f}_tok_per_s"),
            (f"serve_seq_ttft_p99_{n}", "0",
             f"{1e3 * p99(seq['ttft']):.1f}_ms"),
            (f"serve_speedup_{n}", "0", f"{speedup:.1f}x"),
        ]
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
    if speedup_100 < SPEEDUP_GATE:
        print(f"serve_gate,0,FAILED_need_{SPEEDUP_GATE}x", flush=True)
        sys.exit(1)
    print(f"serve_gate,0,PASS_ge_{SPEEDUP_GATE}x")


if __name__ == "__main__":
    main()
