"""Observability overhead benchmark: the engine-throughput workload run
with tracing inert vs fully on.

The observability layer promises to be cheap enough to leave enabled in
production: the metrics bridge and flight recorder are always-on bus
subscribers, and this benchmark answers whether turning the *tracer* on
(the only opt-in piece, and the only one on the per-step hot path) costs
engine throughput.  The workload is the same 4-block inline run as
engine_throughput.py's client-driven baseline — serial sim step chains,
no HTTP — so any gap is pure span-recording overhead in the daemon
dispatch/harvest path.

Acceptance gate (wired into CI via run.py --only obs): tracing-on
aggregate steps/s within OVERHEAD_BUDGET_PCT of tracing-off.  The script
exits non-zero past the budget, which run.py turns into ok:false in
BENCH_obs.json.

Output follows the repo's benchmark CSV convention: name,us_per_call,
derived.

    PYTHONPATH=src python benchmarks/obs_overhead.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.daemon import ClusterDaemon
from repro.core.runtime import SimJobSpec
from repro.core.topology import Topology
from repro.obs.flight import RECORDER
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER

N_BLOCKS = 4
STEPS = 150
STEP_S = 0.003
REPEATS = 3
OVERHEAD_BUDGET_PCT = 5.0


def run_once(trace: bool) -> float:
    """One inline engine run; returns aggregate steps/s."""
    # scrub process-global observability state so runs don't see each
    # other (the tracer especially: a previous trace=True run leaves the
    # enabled flag set)
    TRACER.disable()
    TRACER.reset()
    REGISTRY.reset()
    RECORDER.reset()
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root="artifacts/obs_bench_ckpt",
                           background=False, trace=trace)
    apps = []
    for i in range(N_BLOCKS):
        app, grant = daemon.submit(f"u{i}", f"obs bench {i}", 4,
                                   job=SimJobSpec(step_s=STEP_S))
        assert grant is not None
        apps.append(app)
    t0 = time.perf_counter()
    daemon.run_steps({a: STEPS for a in apps})
    wall = time.perf_counter() - t0
    for a in apps:
        assert daemon.runtime(a).step_count == STEPS
        daemon.expire(a)
    if trace:
        assert TRACER.enabled, "trace=True run must leave the tracer on"
        n_spans = len(TRACER.spans())
        assert n_spans > 0, "tracing-on run recorded no spans"
    daemon.stop()
    TRACER.disable()
    return N_BLOCKS * STEPS / wall


def best_of(trace: bool) -> float:
    return max(run_once(trace) for _ in range(REPEATS))


def main() -> int:
    off = best_of(trace=False)
    on = best_of(trace=True)
    overhead_pct = max(0.0, (off - on) / off * 100.0)
    # us_per_call column: mean wall per step, in microseconds
    print(f"obs_off_steps_per_s,{1e6 / off:.1f},{off:.1f}")
    print(f"obs_on_steps_per_s,{1e6 / on:.1f},{on:.1f}")
    print(f"obs_overhead_pct,0,{overhead_pct:.2f}")
    if overhead_pct > OVERHEAD_BUDGET_PCT:
        print(f"FAIL: tracing overhead {overhead_pct:.2f}% exceeds "
              f"{OVERHEAD_BUDGET_PCT:.1f}% budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
