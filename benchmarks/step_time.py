"""Step-time floor: fused-optimizer + overlapped-allreduce benchmark.

Two deterministic models plus measured wall-clock rows:

* **Optimizer HBM-bytes model** — the fused AdamW kernel touches each
  param/grad/moment element exactly once per direction (one read pass, one
  write pass); the composed reference re-materializes the fp32 moments and
  the delta chain through HBM.  Rows ``opt_hbm_model_{f32,i8}_speedup_model``
  carry the modeled speedup as ``derived`` — the CI gate
  (``benchmarks/check_step_time.py``) fails if the int8-state row (the
  production 400B-class configuration, see ``dryrun.TRAIN_OVERRIDES``)
  drops below 1.5x or the f32 row below 1.0x.
* **Overlap model** — per-microbatch int8-compressed gradient allreduce
  folded into the accumulation scan vs. one uncompressed f32 allreduce after
  it: exposed communication drops from P*4B/link_bw to the un-hideable
  remainder of P*1B/link_bw behind per-microbatch compute.
* **Measured** — ``optimizer.apply`` fused ("jnp" fallback: same op fusion
  the TPU kernel locks in) vs composed reference, and ``make_train_step``
  serial vs ``overlap_comm=True`` on a 1-pod mesh.  Wall-clock on the CI
  host, so the trend gate compares medians with 10% slack.

Run via benchmarks/run.py (section ``step_time``); prints the harness CSV.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ.setdefault("XLA_FLAGS", "")

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import pipeline
from repro.launch.hlo_analysis import LINK_BW, PEAK_FLOPS
from repro.models.config import ShapeConfig
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib


# ------------------------------------------------------- HBM-bytes model
def opt_bytes_per_elem(bits):
    """(reference_bytes, fused_bytes) touched in HBM per parameter element.

    Fused: every operand crosses HBM once per direction — read p(2) g(4)
    m v (4+4 fp32, 1+1 int8 + amortized block scales), write p m v.
    Reference: each op in the composed chain round-trips its operands;
    with int8 state the dequantize/requantize each add a full fp32
    materialization plus the abs-max pass of the requantizer.
    """
    p, g = 2, 4                               # bf16 params, f32 grads
    if bits == 8:
        m = v = 1.03                          # int8 q + 1/256-block f32 scale
        fused = (p + g + m + v) + (p + m + v)
        ref = (
            2 * (m + 4)                       # dequant m, v: read q, write f32
            + (g + 4 + 4) + (4 + 4)           # moment update: read g,m,v write
            + (4 + 4 + p) + p                 # delta + param: read m,v,p wr p
            + 2 * (4 + 4 + 4 + m))            # requant m,v: abs-max + scale
    else:
        m = v = 4.0
        fused = (p + g + m + v) + (p + m + v)
        ref = ((g + m + v) + (m + v)          # moment update
               + (m + v + p) + p)             # delta + param write
    return ref, fused


# -------------------------------------------------------- overlap model
def overlap_exposed_comm_s(n_params, n_micro, t_grad_micro_s):
    """(serial_exposed_s, overlap_exposed_s) communication per step."""
    serial = n_params * 4 / LINK_BW                   # one f32 allreduce
    per_micro = n_params * 1 / (LINK_BW * n_micro)    # int8, per microbatch
    exposed = max(0.0, per_micro - t_grad_micro_s) * n_micro
    return serial, exposed


def timed(fn, *args, warmup=2, iters=8):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measured_optimizer(bits):
    """Median us/call of optimizer.apply: composed reference vs fused."""
    key = jax.random.PRNGKey(0)
    params = {"stack": jax.random.normal(key, (8, 512, 512), jnp.bfloat16),
              "w": jax.random.normal(key, (512, 512), jnp.bfloat16)}
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)
    out = {}
    for label, fused in (("ref", "off"), ("fused", "jnp")):
        cfg = opt_lib.OptConfig(state_bits=bits, fused=fused)
        state = opt_lib.init(params, cfg)
        fn = jax.jit(lambda p, s, g, cfg=cfg: opt_lib.apply(cfg, p, s, g))
        out[label] = timed(fn, params, state, grads)
    return out


def measured_train_step():
    """Median us/call of the full train step: serial vs overlap_comm on a
    single-device "pod" mesh (measures the overlap machinery's overhead —
    real savings need real links; the model rows carry those)."""
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("b", "train", seq_len=64, global_batch=4,
                        microbatch=2)
    opt_cfg = opt_lib.OptConfig(warmup_steps=2, total_steps=100)
    batch = pipeline.DataIterator(cfg, shape).batch(0)
    mesh = jax.make_mesh((1,), ("pod",))
    out = {}
    for label, kw in (("serial", {}),
                      ("overlap", {"overlap_comm": True, "mesh": mesh})):
        step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg, **kw))
        state = train_lib.make_train_state(cfg, jax.random.PRNGKey(0),
                                           opt_cfg)
        out[label] = timed(lambda s, b: step(s, b)[0], state, batch)
    return out


def main():
    print("name,us_per_call,derived")
    # deterministic HBM model rows — the CI floor gates on these
    for bits, tag in ((None, "f32"), (8, "i8")):
        ref_b, fused_b = opt_bytes_per_elem(bits)
        print(f"opt_hbm_model_{tag}_speedup_model,0,{ref_b / fused_b:.3f}")
    # overlap model on a 7B-class block: 1 GB of grads, 4 microbatches,
    # per-microbatch grad compute from the compute roofline
    n_params = 1e9
    t_grad = 6 * n_params * 1024 / 4 / PEAK_FLOPS     # tokens per microbatch
    serial_s, overlap_s = overlap_exposed_comm_s(n_params, 4, t_grad)
    print(f"overlap_exposed_comm_serial,{serial_s*1e6:.0f},1.0")
    print(f"overlap_exposed_comm_overlap,{overlap_s*1e6:.0f},"
          f"{overlap_s / serial_s:.4f}")
    hidden = (serial_s - overlap_s) / serial_s if serial_s else 0.0
    print(f"overlap_hidden_frac_model,0,{hidden:.3f}")

    # measured rows (host wall-clock; the trend gate allows 10%)
    for bits, tag in ((None, "f32"), (8, "i8")):
        t = measured_optimizer(bits)
        print(f"opt_apply_{tag}_ref,{t['ref']*1e6:.0f},1.0")
        print(f"opt_apply_{tag}_fused,{t['fused']*1e6:.0f},"
              f"{t['ref'] / t['fused']:.3f}")
    t = measured_train_step()
    print(f"train_step_serial,{t['serial']*1e6:.0f},1.0")
    print(f"train_step_overlap,{t['overlap']*1e6:.0f},"
          f"{t['serial'] / t['overlap']:.3f}")


if __name__ == "__main__":
    main()
