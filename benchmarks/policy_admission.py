"""Tenancy policy vs. plain FIFO on an oversubscribed 6-user workload.

Scenario A (deadline slack, ISSUE 3 acceptance): a 16-chip pod takes six
4-chip jobs (24 > 16).  Four admit immediately; the last two queue.  The
tight-deadline job is submitted *last*, so FIFO admits it last and it
finishes past its SLO; with the policy's least-slack ordering it jumps the
loose-deadline entry inside its fair-share class and finishes in time.
Measures the completion-time deadline-miss rate in both modes (plus the
Monitor's admission-slack accounting) — slack ordering must strictly
reduce it.

Scenario B (quota fairness): a hog submits two 8-chip jobs ahead of two
small 4-chip users.  Without quotas the hog's jobs fill the pod and the
small users wait a whole job duration; with a 8-chip cap the hog's second
job is waitlisted (not denied) and the small users start immediately.

Uses SimRuntime so the comparison isolates *scheduler* semantics from XLA
noise.  Output follows the repo's benchmark CSV convention:
name,us_per_call,derived.

    PYTHONPATH=src python benchmarks/policy_admission.py
"""
import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology

STEP_S = 0.03


def build(pod_x=4, pod_y=4):
    topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                             ckpt_root="artifacts/policy_bench_ckpt")


def run_workload(ctl, jobs):
    """Drive submissions to completion.  ``jobs``: list of dicts with user,
    n_chips, steps, deadline_s (optional).  Returns per-job dicts with
    submitted/admitted/completed wall times."""
    t0 = time.perf_counter()
    info = {}
    for spec in jobs:
        app, grant = ctl.submit(spec["user"], spec["user"], spec["n_chips"],
                                deadline_s=spec.get("deadline_s"),
                                duration_s=60.0)
        rec = {"app": app, "spec": spec,
               "submitted": time.perf_counter() - t0,
               "admitted": None, "completed": None}
        info[app] = rec
    while True:
        for app, rec in info.items():
            blk = ctl.registry.get(app)
            if rec["admitted"] is None and blk.grant is not None and \
                    blk.state == BlockState.APPROVED:
                rec["admitted"] = time.perf_counter() - t0
                ctl.confirm(app, blk.grant.token)
                ctl.registry.set_state(app, BlockState.ACTIVE)
                ctl.registry.set_state(app, BlockState.RUNNING)
                ctl.runtimes[app] = SimRuntime(STEP_S)
        running = ctl.registry.by_state(BlockState.RUNNING)
        if running:
            ctl.run_steps({a: 1 for a in running})
        for app, rec in info.items():
            rt = ctl.runtimes.get(app)
            blk = ctl.registry.get(app)
            if rt is not None and blk.state == BlockState.RUNNING and \
                    rt.step_count >= rec["spec"]["steps"]:
                rec["completed"] = time.perf_counter() - t0
                ctl.registry.set_state(app, BlockState.DONE)
                ctl.expire(app)
        ctl.tick()
        if all(r["completed"] is not None for r in info.values()):
            return list(info.values())


def deadline_scenario(deadline_ordering: bool):
    """6 users x 4 chips on 16: the tight-SLO job arrives last."""
    ctl = build()
    ctl.scheduler.policy.deadline_ordering = deadline_ordering
    short, long_, queued = 5, 20, 10
    jobs = [
        {"user": "u0", "n_chips": 4, "steps": short, "deadline_s": 30.0},
        {"user": "u1", "n_chips": 4, "steps": long_, "deadline_s": 30.0},
        {"user": "u2", "n_chips": 4, "steps": long_, "deadline_s": 30.0},
        {"user": "u3", "n_chips": 4, "steps": long_, "deadline_s": 30.0},
        # both queue behind the four runners; FIFO admits u4 first
        {"user": "u4", "n_chips": 4, "steps": queued, "deadline_s": 30.0},
        {"user": "u5", "n_chips": 4, "steps": queued,
         "deadline_s": (short + queued) * STEP_S + 0.20},   # tight SLO
    ]
    recs = run_workload(ctl, jobs)
    misses = sum(1 for r in recs
                 if r["spec"].get("deadline_s") is not None
                 and r["completed"] - r["submitted"] >
                 r["spec"]["deadline_s"])
    return misses / len(recs), ctl.monitor.deadline_report()


def quota_scenario(use_quota: bool):
    """A hog's two 8-chip jobs vs two 4-chip small users."""
    ctl = build()
    if use_quota:
        ctl.scheduler.policy.set_quota("hog", max_chips=8)
    jobs = [
        {"user": "hog", "n_chips": 8, "steps": 10},
        {"user": "hog", "n_chips": 8, "steps": 10},
        {"user": "sm1", "n_chips": 4, "steps": 5},
        {"user": "sm2", "n_chips": 4, "steps": 5},
    ]
    recs = run_workload(ctl, jobs)
    small_waits = [r["admitted"] - r["submitted"] for r in recs
                   if r["spec"]["user"].startswith("sm")]
    return statistics.mean(small_waits)


def main():
    miss_fifo, _ = deadline_scenario(deadline_ordering=False)
    miss_slack, rep = deadline_scenario(deadline_ordering=True)
    small_wait_noq = quota_scenario(use_quota=False)
    small_wait_q = quota_scenario(use_quota=True)

    print("name,us_per_call,derived")
    print(f"deadline_miss_rate_fifo,0,{miss_fifo:.3f}")
    print(f"deadline_miss_rate_slack,0,{miss_slack:.3f}")
    print(f"monitor_deadline_miss_rate_slack,0,"
          f"{rep['deadline_miss_rate']:.3f}")
    print(f"monitor_min_admission_slack_s,0,"
          f"{rep['min_admission_slack_s']:.3f}")
    print(f"small_user_wait_no_quota_s,{small_wait_noq * 1e6:.0f},"
          f"{small_wait_noq:.4f}")
    print(f"small_user_wait_quota_s,{small_wait_q * 1e6:.0f},"
          f"{small_wait_q:.4f}")
    print(f"quota_fairness_wait_speedup,0,"
          f"{small_wait_noq / max(small_wait_q, 1e-6):.1f}")

    ok = True
    if miss_slack >= miss_fifo:
        print("WARNING: slack ordering did not strictly reduce the "
              "deadline-miss rate vs FIFO", file=sys.stderr)
        ok = False
    if small_wait_q >= small_wait_noq:
        print("WARNING: quota cap did not reduce small-user wait",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
