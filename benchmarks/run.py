"""Benchmark harness — one section per paper artifact.

  Fig. 3  bisection bandwidth, 1 vs 2 blocks   -> benchmarks/bisection.py
          (measured, subprocess w/ 8 host devices) + structural link model
  §4      multi-block overhead on real jobs    -> benchmarks/multiblock_overhead.py
  (assignment) roofline table per cell         -> benchmarks/roofline_report.py
  (scheduler) event-driven vs round-robin      -> benchmarks/scheduler_throughput.py
  (scheduler) preemptive vs wait-for-expiry    -> benchmarks/preemption_latency.py

Prints ``name,us_per_call,derived`` CSV.  Subprocesses own the multi-device
XLA flag so this process (and pytest) keep a single device.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")


def run_sub(script: str, devices: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, os.path.join(HERE, script)],
                       env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        print(f"{script},0,FAILED")
        sys.stderr.write(r.stderr[-2000:])
        return
    for line in r.stdout.splitlines():
        if line and not line.startswith("name,"):
            print(line)


def run_structural() -> None:
    """Structural Fig. 3 model: contiguous TPU blocks share zero links."""
    sys.path.insert(0, SRC)
    from repro.core import interference
    from repro.core.topology import Topology, rect_coords
    topo = Topology(n_pods=1, pod_x=16, pod_y=16)
    a = rect_coords(0, 0, 0, 8, 16)        # half pod
    b = rect_coords(0, 8, 0, 8, 16)        # other half
    rows = interference.predicted_fig3(
        topo, a, b, [2 ** i for i in range(12, 26, 2)])
    for r in rows:
        print(f"fig3_struct_single_{r['bytes']},0,{r['bw_single_GBs']:.2f}")
        print(f"fig3_struct_multi_{r['bytes']},0,{r['bw_multi_GBs']:.2f}")
    print(f"fig3_struct_shared_links,0,{rows[0]['shared_links']}")


def main() -> None:
    print("name,us_per_call,derived")
    print("# --- Fig.3 structural (TPU torus link model) ---")
    run_structural()
    print("# --- Fig.3 measured (8 host devices, 2 blocks) ---")
    run_sub("bisection.py", devices=8)
    print("# --- multi-block overhead on tenant train jobs ---")
    run_sub("multiblock_overhead.py", devices=8)
    print("# --- roofline table (from dry-run artifacts) ---")
    run_sub("roofline_report.py", devices=1)
    print("# --- scheduler: event-driven dispatch vs round-robin ---")
    run_sub("scheduler_throughput.py", devices=1)
    print("# --- scheduler: preemptive admission vs wait-for-expiry ---")
    run_sub("preemption_latency.py", devices=1)


if __name__ == "__main__":
    main()
