"""Benchmark harness — one section per paper artifact.

  Fig. 3  bisection bandwidth, 1 vs 2 blocks   -> benchmarks/bisection.py
          (measured, subprocess w/ 8 host devices) + structural link model
  §4      multi-block overhead on real jobs    -> benchmarks/multiblock_overhead.py
  (assignment) roofline table per cell         -> benchmarks/roofline_report.py
  (scheduler) event-driven vs round-robin      -> benchmarks/scheduler_throughput.py
  (scheduler) preemptive vs wait-for-expiry    -> benchmarks/preemption_latency.py
  (scheduler) policy vs FIFO admission         -> benchmarks/policy_admission.py
  (gateway)   web request rate + feed latency  -> benchmarks/gateway_throughput.py
  (engine)    autostep vs client steps/s + SSE -> benchmarks/engine_throughput.py

Prints ``name,us_per_call,derived`` CSV.  Subprocesses own the multi-device
XLA flag so this process (and pytest) keep a single device.

``--json DIR`` additionally writes one ``BENCH_<section>.json`` per section
(parsed rows + pass/fail) so CI can upload them as artifacts and the perf
trajectory accumulates across PRs.  ``--only a,b`` runs a subset of
sections (CI runs the cheap scheduler ones).
"""
import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")


def parse_rows(lines):
    rows = []
    for line in lines:
        line = line.strip()
        if not line or line.startswith(("name,", "#")):
            continue
        parts = line.split(",")
        if len(parts) == 3:
            rows.append({"name": parts[0], "us_per_call": parts[1],
                         "derived": parts[2]})
    return rows


def write_json(json_dir, section, rows, ok):
    if not json_dir:
        return
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump({"section": section, "ok": ok, "rows": rows}, f, indent=1)


def run_sub(script: str, devices: int, json_dir=None, section=None) -> None:
    section = section or os.path.splitext(script)[0]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, os.path.join(HERE, script)],
                       env=env, capture_output=True, text=True, timeout=1800)
    if r.returncode != 0 and not r.stdout.strip():
        print(f"{script},0,FAILED")
        sys.stderr.write(r.stderr[-2000:])
        write_json(json_dir, section, [], ok=False)
        return
    lines = [l for l in r.stdout.splitlines()
             if l and not l.startswith("name,")]
    for line in lines:
        print(line)
    if r.returncode != 0:
        # partial rows + crash: still surface the failure in the CSV
        print(f"{script},0,FAILED")
        sys.stderr.write(r.stderr[-2000:])
    write_json(json_dir, section, parse_rows(lines), ok=r.returncode == 0)


def run_structural(json_dir=None) -> None:
    """Structural Fig. 3 model: contiguous TPU blocks share zero links."""
    sys.path.insert(0, SRC)
    from repro.core import interference
    from repro.core.topology import Topology, rect_coords
    topo = Topology(n_pods=1, pod_x=16, pod_y=16)
    a = rect_coords(0, 0, 0, 8, 16)        # half pod
    b = rect_coords(0, 8, 0, 8, 16)        # other half
    rows = interference.predicted_fig3(
        topo, a, b, [2 ** i for i in range(12, 26, 2)])
    lines = []
    for r in rows:
        lines.append(f"fig3_struct_single_{r['bytes']},0,"
                     f"{r['bw_single_GBs']:.2f}")
        lines.append(f"fig3_struct_multi_{r['bytes']},0,"
                     f"{r['bw_multi_GBs']:.2f}")
    lines.append(f"fig3_struct_shared_links,0,{rows[0]['shared_links']}")
    for line in lines:
        print(line)
    write_json(json_dir, "fig3_structural", parse_rows(lines), ok=True)


SECTIONS = [
    # (section key, header, script, device count)
    ("fig3_structural", "Fig.3 structural (TPU torus link model)",
     None, 0),
    ("bisection", "Fig.3 measured (8 host devices, 2 blocks)",
     "bisection.py", 8),
    ("multiblock_overhead", "multi-block overhead on tenant train jobs",
     "multiblock_overhead.py", 8),
    ("roofline", "roofline table (from dry-run artifacts)",
     "roofline_report.py", 1),
    ("step_time", "step-time floor: fused optimizer + overlapped allreduce",
     "step_time.py", 1),
    ("scheduler_throughput", "scheduler: event-driven dispatch vs round-robin",
     "scheduler_throughput.py", 1),
    ("preemption_latency", "scheduler: preemptive admission vs wait-for-expiry",
     "preemption_latency.py", 1),
    ("policy_admission", "scheduler: tenancy policy (quota/deadline/gang) vs FIFO",
     "policy_admission.py", 1),
    ("gateway", "web gateway: request throughput + admit-to-event latency",
     "gateway_throughput.py", 1),
    ("engine", "autostep engine: steps/s vs client-driven + SSE fan-out",
     "engine_throughput.py", 1),
    ("serve", "serve data plane: continuous batching vs sequential decode",
     "serve_throughput.py", 1),
    ("federation", "federation: pod-ramp time-to-admit + death blast radius",
     "federation_elasticity.py", 1),
    ("obs", "observability: tracing+metrics overhead on the engine (<=5% gate)",
     "obs_overhead.py", 1),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="DIR", default=None,
                    help="write BENCH_<section>.json artifacts here")
    ap.add_argument("--only", default=None,
                    help="comma-separated section keys to run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    for key, header, script, devices in SECTIONS:
        if only is not None and key not in only:
            continue
        print(f"# --- {header} ---")
        if script is None:
            run_structural(json_dir=args.json)
        else:
            run_sub(script, devices=devices, json_dir=args.json,
                    section=key)


if __name__ == "__main__":
    main()
